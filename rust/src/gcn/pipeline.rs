//! Cross-layer streaming pipeline: an N-layer out-of-core GCN forward
//! under **one** scheduler (the multi-layer extension of the paper's
//! three-phase design).
//!
//! The single-layer path ran each `OocGcnLayer` as an isolated pass — the
//! prefetch pipeline drained at every layer boundary: the producer closed
//! its hand-off after the layer's last segment, the consumer ran Phase III,
//! and the next layer started staging from a cold pipeline. This module
//! removes the drain. [`OocGcnModel`] concatenates every layer's RoBW plan
//! into one global segment index space and runs a single
//! [`Prefetch::run_recycling`](crate::runtime::prefetch::Prefetch::run_recycling)
//! over it, so the producer *rolls onto the next layer's plan instead of
//! closing the hand-off*: while the calling thread finishes layer `l`'s
//! last partials and its Phase III combine, the producer is already
//! staging layer `l+1`'s first segments (its Phase I panel reservation and
//! Phase II reads need nothing from layer `l`'s output — only the
//! *compute* does, and consumption stays strictly index-ordered).
//!
//! Intermediate feature panels can spill through the same tiered store the
//! adjacency segments use: with a [`PanelStore`] attached
//! ([`PipelineConfig::panel_spill`]), layer `l`'s combined output is
//! written to disk in the [`segio`](crate::sparse::segio) dense-panel
//! record format (checksummed, golden-vector pinned) and read back —
//! through the store's deterministic-LRU host tier — as layer `l+1`'s
//! Phase I input, so no intermediate activation has to stay resident in
//! host RAM between layers. Panel bytes round-trip as raw f32 bit
//! patterns, so a spilling pass is byte-identical to one that keeps every
//! panel in memory.
//!
//! Determinism rule (unchanged): consumption is strictly ordered over the
//! global index space, partials land in fixed disjoint row ranges, and
//! combines run in layer order — so the pipelined multi-layer output is
//! **byte-identical to the sequential per-layer oracle** at every prefetch
//! depth, thread count, cache size, and backing, with or without panel
//! spilling (`rust/tests/differential.rs`). The `GpuMem` ledger is the one
//! timing-dependent observable: with cross-layer overlap it may briefly
//! hold layer `l`'s panel alongside layer `l+1`'s staged-ahead segments,
//! so its peak (and OOM behaviour *near* the capacity boundary) reflects
//! real staging concurrency, exactly as at depth > 1 within one layer.

use crate::gcn::model::dense_affine;
use crate::gcn::oocgcn::{LayerReport, OocGcnLayer, StagingBacking, StagingConfig};
use crate::memsim::{GpuMem, Op, StagingMeter};
use crate::partition::robw::{materialize_into, robw_partition_par, RobwSegment};
use crate::runtime::heal::{read_panel_healing, read_segment_healing, HealStats, RebuildSource};
use crate::runtime::pool::Pool;
use crate::runtime::recycle::BufferPool;
use crate::runtime::segstore::{MappedPanelChunks, PanelRead, PanelSrc, PanelStore, SegmentRead};
use crate::runtime::tile_exec::{BsrSpmmExec, CombineExec};
use crate::runtime::Executor;
use crate::sparse::spmm::{spmm_view_par_into, Dense, RowSrc};
use crate::sparse::Csr;
use anyhow::{anyhow, bail, Result};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// Configuration of one multi-layer pipelined forward pass.
#[derive(Debug, Clone, Default)]
pub struct PipelineConfig {
    /// Phase II staging configuration, shared by every layer: prefetch
    /// depth, segment backing (in-memory or a spilled
    /// [`SegmentStore`](crate::runtime::segstore::SegmentStore)),
    /// optional charged I/O cost, and the buffer-recycle pool.
    pub staging: StagingConfig,
    /// When set, every *intermediate* feature panel (layer `l`'s output,
    /// `l < N-1`) spills to this store after Phase III and is read back —
    /// through its host cache — at layer `l+1`'s Phase I, instead of
    /// staying resident in host RAM across the boundary. The final
    /// layer's output is always returned in memory. Output is
    /// byte-identical either way.
    pub panel_spill: Option<Arc<PanelStore>>,
}

impl PipelineConfig {
    /// Serial staging (depth 1, in-memory, fresh allocations, no panel
    /// spilling): the oracle configuration.
    pub fn serial() -> PipelineConfig {
        PipelineConfig { staging: StagingConfig::serial(), panel_spill: None }
    }

    /// Pipeline over the given staging configuration, panels in RAM.
    pub fn staged(staging: StagingConfig) -> PipelineConfig {
        PipelineConfig { staging, panel_spill: None }
    }

    /// The same configuration with intermediate panels spilled through
    /// `store`.
    pub fn with_panel_spill(mut self, store: Arc<PanelStore>) -> PipelineConfig {
        self.panel_spill = Some(store);
        self
    }
}

/// Execution report of one multi-layer pass: one [`LayerReport`] per layer
/// plus the panel-tier traffic of the pass.
#[derive(Debug, Clone, Default)]
pub struct PipelineReport {
    /// Per-layer reports, in layer order. Deterministic per layer (the
    /// producer stages each layer's segments strictly in order) except for
    /// `peak_gpu_bytes`, which reflects staging concurrency.
    pub per_layer: Vec<LayerReport>,
    /// Bytes written to the panel tier (0 without panel spilling).
    pub panel_spill_bytes: u64,
    /// Measured bytes read back from panel files (0 on host-tier hits).
    pub panel_read_bytes: u64,
    /// Panel reads served by the panel store's host cache.
    pub panel_cache_hits: usize,
    /// Panel reads that went to disk.
    pub panel_cache_misses: usize,
}

impl PipelineReport {
    /// Merge the per-layer reports into one pass-wide [`LayerReport`]:
    /// additive fields are summed, `peak_gpu_bytes` and `prefetch_depth`
    /// are maxima.
    pub fn merged(&self) -> LayerReport {
        let mut m = LayerReport::default();
        for r in &self.per_layer {
            m.segments += r.segments;
            m.artifact_calls_estimate += r.artifact_calls_estimate;
            m.peak_gpu_bytes = m.peak_gpu_bytes.max(r.peak_gpu_bytes);
            m.h2d_bytes += r.h2d_bytes;
            m.prefetch_depth = m.prefetch_depth.max(r.prefetch_depth);
            m.disk_bytes += r.disk_bytes;
            m.cache_hits += r.cache_hits;
            m.cache_misses += r.cache_misses;
            m.staged_io_modeled_s += r.staged_io_modeled_s;
            m.heal.merge(&r.heal);
        }
        m
    }

    /// The sole layer's report — the single-layer wrappers'
    /// (`OocGcnLayer::{forward_staged, forward_cpu}`) return value.
    pub(crate) fn into_single(mut self) -> LayerReport {
        debug_assert_eq!(self.per_layer.len(), 1);
        self.per_layer.pop().expect("single-layer pipeline report")
    }
}

/// An N-layer out-of-core GCN: an ordered list of [`OocGcnLayer`]s run
/// under one cross-layer scheduler.
pub struct OocGcnModel {
    /// The layers, in forward order. Adjacent widths must chain
    /// (`layers[l].w.ncols == layers[l+1].w.nrows`, checked by
    /// [`OocGcnModel::new`]).
    pub layers: Vec<OocGcnLayer>,
}

impl OocGcnModel {
    /// Build a model, validating that adjacent layer widths chain.
    pub fn new(layers: Vec<OocGcnLayer>) -> Result<OocGcnModel> {
        if layers.is_empty() {
            bail!("a GCN model needs at least one layer");
        }
        for (l, w) in layers.windows(2).enumerate() {
            if w[0].w.ncols != w[1].w.nrows {
                bail!(
                    "layer {l} outputs width {} but layer {} expects width {}",
                    w[0].w.ncols,
                    l + 1,
                    w[1].w.nrows
                );
            }
        }
        Ok(OocGcnModel { layers })
    }

    /// Number of layers.
    pub fn len(&self) -> usize {
        self.layers.len()
    }

    /// Whether the model has no layers (never true for a constructed one).
    pub fn is_empty(&self) -> bool {
        self.layers.is_empty()
    }

    /// Artifact-free pipelined multi-layer forward: per-segment
    /// aggregation on [`spmm_view_par_into`] straight into the pass-wide panel,
    /// host-side combines, one cross-layer prefetch pipeline. This is the
    /// execution surface the differential suite drives; its output is
    /// byte-identical to [`Self::forward_cpu_sequential`] at every
    /// configuration point.
    pub fn forward_cpu(
        &self,
        a_hat: &Csr,
        x: &Dense,
        mem: &mut GpuMem,
        pool: &Pool,
        cfg: &PipelineConfig,
    ) -> Result<(Dense, PipelineReport)> {
        forward_pipelined_cpu(&self.layers, a_hat, x, mem, pool, cfg)
    }

    /// The drain-at-boundary oracle: run each layer as an isolated
    /// single-layer pass (the pre-pipeline behaviour), chaining outputs in
    /// host RAM. Intermediate panels are never spilled here — the point of
    /// the oracle is the *simplest* correct execution. Used by the
    /// differential suite, `micro_hotpath`'s overlap bench, and the
    /// `gcnstream` CLI verification.
    pub fn forward_cpu_sequential(
        &self,
        a_hat: &Csr,
        x: &Dense,
        mem: &mut GpuMem,
        pool: &Pool,
        cfg: &PipelineConfig,
    ) -> Result<(Dense, PipelineReport)> {
        let mut report = PipelineReport::default();
        let mut cur = None;
        for layer in &self.layers {
            let input = cur.as_ref().unwrap_or(x);
            let (out, rep) = layer.forward_cpu(a_hat, input, mem, pool, &cfg.staging)?;
            report.per_layer.push(rep);
            cur = Some(out);
        }
        Ok((cur.expect("model has at least one layer"), report))
    }

    /// Pipelined multi-layer forward through the PJRT artifacts: each
    /// segment's aggregation runs the `bsr_spmm` artifact, each Phase III
    /// combine the fused `gcn_combine` artifact, under the same
    /// cross-layer scheduler as [`Self::forward_cpu`].
    pub fn forward_staged(
        &self,
        exec: &mut Executor,
        a_hat: &Csr,
        x: &Dense,
        mem: &mut GpuMem,
        pool: &Pool,
        cfg: &PipelineConfig,
    ) -> Result<(Dense, PipelineReport)> {
        forward_pipelined_staged(&self.layers, exec, a_hat, x, mem, pool, cfg)
    }
}

/// CPU-compute instantiation of the cross-layer engine (shared by
/// [`OocGcnModel::forward_cpu`] and the single-layer
/// `OocGcnLayer::forward_cpu` wrapper).
pub(crate) fn forward_pipelined_cpu(
    layers: &[OocGcnLayer],
    a_hat: &Csr,
    x: &Dense,
    mem: &mut GpuMem,
    pool: &Pool,
    cfg: &PipelineConfig,
) -> Result<(Dense, PipelineReport)> {
    forward_pipelined(
        layers,
        &mut (),
        a_hat,
        x,
        mem,
        pool,
        cfg,
        &mut |_, _, seg, sub, x_l, agg| {
            // Match the panel source once so the nnz loop runs on a
            // monomorphized kernel (no per-row dispatch on the hot path).
            let f = x_l.ncols();
            let out = &mut agg.data[seg.row_lo * f..seg.row_hi * f];
            match x_l {
                PanelSrc::Dense(d) => spmm_view_par_into(sub.view(), d, pool, out),
                PanelSrc::Mapped(m) => spmm_view_par_into(sub.view(), m, pool, out),
            }
            Ok(())
        },
        &mut |_, l, agg| Ok(dense_affine(agg, &layers[l].w, &layers[l].b, layers[l].relu)),
    )
}

/// Artifact-compute instantiation of the cross-layer engine (shared by
/// [`OocGcnModel::forward_staged`] and the single-layer
/// `OocGcnLayer::forward_staged` wrapper). Per-layer `bsr_spmm` /
/// `gcn_combine` executors are resolved up front so a missing artifact
/// fails before any staging.
pub(crate) fn forward_pipelined_staged(
    layers: &[OocGcnLayer],
    exec: &mut Executor,
    a_hat: &Csr,
    x: &Dense,
    mem: &mut GpuMem,
    pool: &Pool,
    cfg: &PipelineConfig,
) -> Result<(Dense, PipelineReport)> {
    let widths = layer_widths(layers, x.ncols)?;
    let mut kernels = Vec::with_capacity(layers.len());
    for (l, layer) in layers.iter().enumerate() {
        let sp = BsrSpmmExec::for_feature_width(exec, widths[l])?;
        let cb = CombineExec::for_widths(exec, widths[l], layer.w.ncols, layer.relu)?;
        kernels.push((sp, cb));
    }
    let mut calls = vec![0usize; layers.len()];
    let (out, mut rep) = forward_pipelined(
        layers,
        exec,
        a_hat,
        x,
        mem,
        pool,
        cfg,
        &mut |exec, l, seg, sub, x_l, agg| {
            let (sp, _) = &kernels[l];
            let denom = sp.shape.nb * sp.shape.bm * sp.shape.bk;
            // The tile packer consumes materialized CSR + Dense operands,
            // so mapped reads copy here; the CPU path stays zero-copy.
            let owned_sub;
            let sub: &Csr = match sub {
                SegmentRead::Mapped(m) => {
                    owned_sub = m.to_csr();
                    &owned_sub
                }
                other => other.csr(),
            };
            let owned_x;
            let x_l: &Dense = match x_l {
                PanelSrc::Dense(d) => d,
                PanelSrc::Mapped(m) => {
                    owned_x = m.to_dense();
                    &owned_x
                }
            };
            calls[l] += sub.nnz().div_ceil(denom);
            let part = sp.spmm_with_pool(exec, sub, x_l, pool)?;
            agg.data[seg.row_lo * x_l.ncols..seg.row_hi * x_l.ncols]
                .copy_from_slice(&part.data);
            Ok(())
        },
        &mut |exec, l, agg| kernels[l].1.combine(exec, agg, &layers[l].w, &layers[l].b),
    )?;
    for (r, c) in rep.per_layer.iter_mut().zip(calls) {
        r.artifact_calls_estimate = c;
    }
    Ok((out, rep))
}

/// Input feature width per layer, validating the chain starts at `f0`.
/// Crate-visible: `gcn::train_stream` validates the same chain before a
/// streamed training step and sizes its backward scratch from it.
pub(crate) fn layer_widths(layers: &[OocGcnLayer], f0: usize) -> Result<Vec<usize>> {
    let mut widths = Vec::with_capacity(layers.len());
    let mut w = f0;
    for (l, layer) in layers.iter().enumerate() {
        if layer.w.nrows != w {
            bail!("layer {l}: weight rows {} do not match input width {w}", layer.w.nrows);
        }
        widths.push(w);
        w = layer.w.ncols;
    }
    Ok(widths)
}

/// Poison-tolerant ledger lock: the ledger holds plain counters that are
/// valid at every instruction boundary, so when a worker panics mid-pass
/// (poisoning the mutex on its way down) the *original* panic must surface
/// — not a secondary `PoisonError` unwrap that masks it. (This replaces
/// the old `stream_segments` `.lock().unwrap()`s.)
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Ledger state shared between the staging producer and the consumer:
/// segment and feature-panel bytes alloc'd but not yet freed (so an
/// aborted pipeline can reconcile exactly what was stranded), plus one
/// [`StagingMeter`] per layer for measured disk I/O.
struct LedgerState<'a> {
    mem: &'a mut GpuMem,
    /// Staged segment bytes not yet freed by a consume.
    staged: u64,
    /// Feature-panel bytes (Phase I residency) not yet freed by a finish.
    panels: u64,
    meters: Vec<StagingMeter>,
    /// Per-layer recovery counters — accumulated under the lock because
    /// the producer closure is `Fn`, like `meters`. Kept separate from the
    /// meters so the oracle comparison (meters equal at every sweep point)
    /// stays exact: only these may differ on a healed run.
    heals: Vec<HealStats>,
}

/// The consumer's view of the current layer's input panel.
enum XCur<'a> {
    /// The caller's input (layer 0).
    Borrowed(&'a Dense),
    /// A previous layer's output held in host RAM.
    Owned(Dense),
    /// A previous layer's output served shared from the panel-store host
    /// tier.
    Shared(Arc<Dense>),
    /// A previous layer's output served as page-cache-backed chunk
    /// mappings (`staging.mmap` with panel spilling): rows are read
    /// straight out of the mapped files, never copied into a host slab.
    Mapped(MappedPanelChunks),
    /// A previous layer's output spilled to the panel store, not yet read
    /// back (becomes `Owned`/`Shared`/`Mapped` at the next layer's first
    /// segment).
    Spilled,
}

impl XCur<'_> {
    fn src(&self) -> PanelSrc<'_> {
        match self {
            XCur::Borrowed(p) => PanelSrc::Dense(p),
            XCur::Owned(p) => PanelSrc::Dense(p),
            XCur::Shared(p) => PanelSrc::Dense(p),
            XCur::Mapped(m) => PanelSrc::Mapped(m),
            XCur::Spilled => unreachable!("panel read back before the layer's first consume"),
        }
    }

    /// Retire an owned panel's slab to the recycle pool when this view is
    /// replaced or abandoned.
    fn retire(&mut self, recycle: Option<&BufferPool>) {
        if let XCur::Owned(p) = std::mem::replace(self, XCur::Spilled) {
            if let Some(rp) = recycle {
                rp.put_panel(p.data);
            }
        }
    }
}

/// The cross-layer streaming engine. One prefetch pipeline spans every
/// layer's RoBW plan; `consume` computes one segment's partial into the
/// current layer's aggregation panel on the calling thread, `finish` turns
/// a full aggregation into that layer's output (Phase III). `ctx` is
/// whatever mutable state both need (the PJRT executor on the artifact
/// path, `()` on the CPU path).
///
/// Phase structure per layer `l`, embedded in the one pipeline:
/// * **Phase I** — the producer reserves layer `l`'s input-panel bytes on
///   the ledger immediately before staging its first segment (so panel
///   residency precedes that layer's Phase II exactly as in the
///   single-layer pass), and the consumer materializes the panel — reading
///   it back from the panel store when the previous layer spilled it — at
///   the layer's first consume.
/// * **Phase II** — segments stage through the shared producer, which
///   rolls from plan `l` straight onto plan `l+1`.
/// * **Phase III** — at the layer's last consume the combine runs, the
///   panel bytes are freed, and the output either becomes the next
///   layer's input in RAM or spills to the panel store.
///
/// The ledger ends balanced on success and on every error path: stranded
/// segments *and* panel reservations are reconciled after the producer has
/// joined, and aggregation/input slabs retire to the recycle pool.
///
/// Crate-visible so `gcn::train_stream` can drive the same engine with a
/// `finish` that additionally spills each layer's aggregated input for the
/// backward pass's reload policy.
#[allow(clippy::too_many_arguments)]
pub(crate) fn forward_pipelined<Ctx>(
    layers: &[OocGcnLayer],
    ctx: &mut Ctx,
    a_hat: &Csr,
    x0: &Dense,
    mem: &mut GpuMem,
    pool: &Pool,
    cfg: &PipelineConfig,
    consume: &mut dyn FnMut(
        &mut Ctx,
        usize,
        &RobwSegment,
        &SegmentRead,
        PanelSrc<'_>,
        &mut Dense,
    ) -> Result<()>,
    finish: &mut dyn FnMut(&mut Ctx, usize, &Dense) -> Result<Dense>,
) -> Result<(Dense, PipelineReport)> {
    let staging = &cfg.staging;
    let nl = layers.len();
    if nl == 0 {
        bail!("a GCN model needs at least one layer");
    }
    let widths = layer_widths(layers, x0.ncols)?;

    // Plan every layer first: a disk-backed pass must match the store's
    // manifest for *every* layer before anything is allocated, or the
    // files on disk and the plans in memory would silently disagree.
    // Layers share the adjacency, so a repeated seg_budget (the common
    // case — every in-repo model uses one budget) reuses the plan of the
    // first layer that computed it instead of re-running the partition
    // scan per layer.
    let mut plans: Vec<Vec<RobwSegment>> = Vec::with_capacity(nl);
    for layer in layers {
        let planned = plans.len();
        match layers[..planned].iter().position(|p| p.seg_budget == layer.seg_budget) {
            Some(prev) => {
                let plan = plans[prev].clone();
                plans.push(plan);
            }
            None => plans.push(robw_partition_par(a_hat, layer.seg_budget, pool)),
        }
    }
    if let StagingBacking::Disk(store) = &staging.backing {
        for (l, plan) in plans.iter().enumerate() {
            store.check_plan(plan).map_err(|e| {
                anyhow!("layer {l}: segment store does not match the RoBW plan: {e}")
            })?;
        }
    }

    // Global index space: layer l owns [starts[l], starts[l + 1]).
    let mut starts = Vec::with_capacity(nl + 1);
    let mut acc = 0usize;
    for p in &plans {
        starts.push(acc);
        acc += p.len();
    }
    starts.push(acc);
    let n_total = acc;

    let panel_bytes: Vec<u64> = widths.iter().map(|&f| (a_hat.nrows * f * 4) as u64).collect();
    let mut reports: Vec<LayerReport> = plans
        .iter()
        .map(|p| LayerReport {
            segments: p.len(),
            prefetch_depth: staging.prefetch.depth.max(1),
            ..Default::default()
        })
        .collect();

    // A 0-row matrix plans zero segments for every layer; run the combine
    // chain directly (each layer's aggregation is the empty panel).
    if n_total == 0 {
        let mut out = Dense::zeros(0, x0.ncols);
        for l in 0..nl {
            out = finish(ctx, l, &Dense::zeros(a_hat.nrows, widths[l]))?;
        }
        return Ok((out, PipelineReport { per_layer: reports, ..PipelineReport::default() }));
    }

    let recycle = staging.recycle.as_deref();
    // Scratch maxima across every layer's plan, used only by recycled
    // in-memory staging (the disk path uses the store's precomputed ones):
    // the first take per in-flight slot covers every later segment of
    // every layer, so capacities never regrow mid-pass.
    let (max_rows, max_nnz) = match (&staging.backing, recycle) {
        (StagingBacking::Memory, Some(_)) => (
            plans.iter().flatten().map(|s| s.row_hi - s.row_lo).max().unwrap_or(0),
            plans.iter().flatten().map(|s| s.nnz).max().unwrap_or(0),
        ),
        _ => (0, 0),
    };
    // Every plan is non-empty here (n_total > 0 and all layers share the
    // matrix), so `starts` is strictly increasing and the layer of global
    // index g is the last start at or before it.
    let locate = |g: usize| -> (usize, usize) {
        let l = starts.partition_point(|&s| s <= g) - 1;
        (l, g - starts[l])
    };

    let ledger = Mutex::new(LedgerState {
        mem,
        staged: 0,
        panels: 0,
        meters: vec![StagingMeter::default(); nl],
        heals: vec![HealStats::default(); nl],
    });

    // Consumer-side state (all touched only on the calling thread).
    let mut x_cur = XCur::Borrowed(x0);
    let mut agg: Option<Dense> = None;
    let mut final_out: Option<Dense> = None;
    let mut panel_spill_bytes = 0u64;
    let mut panel_read_bytes = 0u64;
    let mut panel_hits = 0usize;
    let mut panel_misses = 0usize;

    let streamed = staging.prefetch.run_recycling(
        pool,
        n_total,
        // ---- Producer: Phase I panel reservation + Phase II staging.
        |g: usize, reuse: Option<Csr>| {
            let (l, i) = locate(g);
            let seg = &plans[l][i];
            {
                let mut led = lock(&ledger);
                if i == 0 {
                    // Phase I of layer l: its input panel becomes resident
                    // before the layer's first segment stages — the same
                    // ledger order as the single-layer pass.
                    led.mem.alloc(panel_bytes[l], "feature panel").map_err(|e| {
                        anyhow!("layer {l}: feature panel does not fit: {e}")
                    })?;
                    led.panels += panel_bytes[l];
                }
                led.mem
                    .alloc(seg.bytes, "RoBW segment")
                    .map_err(|e| anyhow!("layer {l}: segment does not fit: {e}"))?;
                led.staged += seg.bytes;
            }
            match &staging.backing {
                StagingBacking::Memory => {
                    let mut sub = match (reuse, recycle) {
                        (Some(m), _) => m,
                        (None, Some(rp)) => rp.take_csr(max_rows, max_nnz),
                        (None, None) => Csr::empty(0, 0),
                    };
                    materialize_into(a_hat, seg, &mut sub);
                    if let Some(cm) = &staging.io_cost {
                        let dur = cm.transfer_secs(Op::HtoD, seg.bytes);
                        std::thread::sleep(std::time::Duration::from_secs_f64(dur));
                    }
                    Ok(SegmentRead::Owned(sub))
                }
                StagingBacking::Disk(store) => {
                    // The healing wrapper is a pass-through under the
                    // default policy; its stats land on the ledger even
                    // when the read ultimately fails, so an aborted pass
                    // still accounts the recovery it attempted.
                    let mut heal = HealStats::default();
                    let res = read_segment_healing(
                        store,
                        i,
                        reuse,
                        recycle,
                        staging.mmap,
                        &staging.heal,
                        staging.chaos.as_deref(),
                        Some(RebuildSource { a: a_hat, seg }),
                        &mut heal,
                    );
                    let mut led = lock(&ledger);
                    led.heals[l].merge(&heal);
                    let (sub, origin) = res
                        .map_err(|e| anyhow!("layer {l}: staging segment {i} from disk: {e}"))?;
                    led.meters[l].record(origin.disk_bytes, origin.cache_hit);
                    Ok(sub)
                }
            }
        },
        // ---- Consumer: Phase II compute + Phase III at layer boundaries.
        |g: usize, sub: SegmentRead| {
            let (l, i) = locate(g);
            let seg = &plans[l][i];
            if i == 0 {
                // Layer open: materialize the input panel (reading back a
                // spilled one) and take this layer's aggregation panel.
                if let XCur::Spilled = x_cur {
                    let ps = cfg.panel_spill.as_ref().expect("spilled only with a store");
                    let mut heal = HealStats::default();
                    let res = read_panel_healing(
                        ps,
                        l - 1,
                        recycle,
                        staging.mmap,
                        &staging.heal,
                        staging.chaos.as_deref(),
                        &mut heal,
                    );
                    reports[l].heal.merge(&heal);
                    let (panel, origin) = res.map_err(|e| {
                        anyhow!("layer {l}: reading back spilled feature panel: {e}")
                    })?;
                    panel_read_bytes += origin.disk_bytes;
                    if origin.cache_hit {
                        panel_hits += 1;
                    } else {
                        panel_misses += 1;
                    }
                    x_cur = match panel {
                        PanelRead::Owned(p) => XCur::Owned(p),
                        PanelRead::Shared(p) => XCur::Shared(p),
                        PanelRead::Mapped(m) => XCur::Mapped(m),
                    };
                }
                agg = Some(match recycle {
                    Some(rp) => Dense::from_vec(
                        a_hat.nrows,
                        widths[l],
                        rp.take_panel(a_hat.nrows * widths[l]),
                    ),
                    None => Dense::zeros(a_hat.nrows, widths[l]),
                });
            }
            consume(
                ctx,
                l,
                seg,
                &sub,
                x_cur.src(),
                agg.as_mut().expect("aggregation panel taken at layer open"),
            )?;
            reports[l].h2d_bytes += seg.bytes;
            {
                let mut led = lock(&ledger);
                led.mem.free(seg.bytes);
                led.staged -= seg.bytes;
            }
            let give_back = if recycle.is_some() { sub.reclaim() } else { None };
            if i + 1 == plans[l].len() {
                // Phase III: combine, then retire the aggregation slab on
                // every path (the `?` runs after it is back in the pool).
                let full = agg.take().expect("aggregation panel present at layer close");
                let finished = finish(ctx, l, &full);
                if let Some(rp) = recycle {
                    rp.put_panel(full.data);
                }
                let out = finished?;
                {
                    let mut led = lock(&ledger);
                    led.mem.free(panel_bytes[l]);
                    led.panels -= panel_bytes[l];
                    reports[l].peak_gpu_bytes = led.mem.peak;
                }
                x_cur.retire(recycle);
                if l + 1 == nl {
                    final_out = Some(out);
                } else if let Some(ps) = &cfg.panel_spill {
                    // Under mmap, segment the panel at the next layer's
                    // plan boundaries so each staged segment's
                    // aggregation window maps the fewest chunk records.
                    let spilled = if staging.mmap {
                        let row_starts: Vec<usize> =
                            plans[l + 1].iter().map(|s| s.row_lo).collect();
                        ps.put_chunked(l, &out, &row_starts)
                    } else {
                        ps.put(l, &out)
                    };
                    let bytes = spilled.map_err(|e| {
                        anyhow!("layer {l}: spilling feature panel to disk: {e}")
                    })?;
                    panel_spill_bytes += bytes;
                    if let Some(rp) = recycle {
                        rp.put_panel(out.data);
                    }
                    x_cur = XCur::Spilled;
                } else {
                    x_cur = XCur::Owned(out);
                }
            }
            Ok(give_back)
        },
    );

    // The producer has joined; reconcile whatever an abort stranded —
    // staged-but-unconsumed segments and unreleased panel reservations.
    let led = ledger.into_inner().unwrap_or_else(PoisonError::into_inner);
    if led.staged > 0 {
        led.mem.free(led.staged);
    }
    if led.panels > 0 {
        led.mem.free(led.panels);
    }
    // Retire consumer-side slabs an abort left behind.
    if let (Some(a), Some(rp)) = (agg.take(), recycle) {
        rp.put_panel(a.data);
    }
    x_cur.retire(recycle);
    let leftovers = streamed?;
    if let Some(rp) = recycle {
        for m in leftovers {
            rp.put_csr(m);
        }
    }

    // Fill the deterministic measured-I/O fields per layer.
    for (l, r) in reports.iter_mut().enumerate() {
        let meter = &led.meters[l];
        r.disk_bytes = meter.disk_bytes;
        r.cache_hits = meter.cache_hits;
        r.cache_misses = meter.cache_misses;
        if let Some(cm) = &staging.io_cost {
            r.staged_io_modeled_s = meter.modeled_read_secs(cm);
        }
        r.heal.merge(&led.heals[l]);
    }
    Ok((
        final_out.expect("last layer finished on the success path"),
        PipelineReport {
            per_layer: reports,
            panel_spill_bytes,
            panel_read_bytes,
            panel_cache_hits: panel_hits,
            panel_cache_misses: panel_misses,
        },
    ))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::segstore::SegmentStore;
    use crate::sparse::norm::normalize_adjacency;
    use crate::sparse::spmm::spmm;
    use crate::testing::TempDir;
    use crate::util::rng::Pcg;

    fn test_layer(rng: &mut Pcg, f: usize, h: usize, seg_budget: u64) -> OocGcnLayer {
        OocGcnLayer {
            w: Dense::from_vec(f, h, (0..f * h).map(|_| (rng.normal() * 0.2) as f32).collect()),
            b: vec![0.05; h],
            relu: true,
            seg_budget,
        }
    }

    fn test_model(rng: &mut Pcg, f: usize, n_layers: usize, seg_budget: u64) -> OocGcnModel {
        OocGcnModel::new((0..n_layers).map(|_| test_layer(rng, f, f, seg_budget)).collect())
            .unwrap()
    }

    /// Closed-form reference: chain spmm + dense_affine per layer.
    fn reference_forward(model: &OocGcnModel, a_hat: &Csr, x: &Dense) -> Dense {
        let mut cur = x.clone();
        for l in &model.layers {
            cur = dense_affine(&spmm(a_hat, &cur), &l.w, &l.b, l.relu);
        }
        cur
    }

    #[test]
    fn model_rejects_unchained_widths() {
        let mut rng = Pcg::seed(20);
        let a = test_layer(&mut rng, 8, 8, 1024);
        let b = test_layer(&mut rng, 4, 4, 1024);
        let err = OocGcnModel::new(vec![a, b]).unwrap_err();
        assert!(err.to_string().contains("layer 0"), "{err}");
        assert!(OocGcnModel::new(Vec::new()).is_err());
    }

    #[test]
    fn pipelined_forward_matches_reference_and_sequential() {
        let mut rng = Pcg::seed(21);
        let a = crate::graphgen::kmer::generate(&mut rng, 250, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(250, 8, (0..250 * 8).map(|_| rng.normal() as f32).collect());
        for n_layers in [1usize, 2, 3] {
            let model = test_model(&mut rng, 8, n_layers, 1536);
            let want = reference_forward(&model, &a_hat, &x);
            let mut mem = GpuMem::new(1 << 30);
            let serial = PipelineConfig::serial();
            let (seq, seq_rep) = model
                .forward_cpu_sequential(&a_hat, &x, &mut mem, &Pool::serial(), &serial)
                .unwrap();
            assert_eq!(seq, want, "sequential oracle diverged from closed form");
            assert_eq!(mem.used, 0);
            for depth in [1usize, 2, 4] {
                let mut mem = GpuMem::new(1 << 30);
                let cfg = PipelineConfig::staged(StagingConfig::depth(depth));
                let (got, rep) =
                    model.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(2), &cfg).unwrap();
                assert_eq!(got, want, "layers={n_layers} depth={depth}");
                assert_eq!(mem.used, 0, "ledger must balance");
                assert_eq!(rep.per_layer.len(), n_layers);
                for (r, s) in rep.per_layer.iter().zip(seq_rep.per_layer.iter()) {
                    assert_eq!(r.segments, s.segments);
                    assert_eq!(r.h2d_bytes, s.h2d_bytes);
                }
                let merged = rep.merged();
                assert_eq!(
                    merged.segments,
                    rep.per_layer.iter().map(|r| r.segments).sum::<usize>()
                );
            }
        }
    }

    #[test]
    fn panel_spilling_is_byte_identical_and_measures_io() {
        let mut rng = Pcg::seed(22);
        let a = crate::graphgen::kmer::generate(&mut rng, 220, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(220, 8, (0..220 * 8).map(|_| rng.normal() as f32).collect());
        let model = test_model(&mut rng, 8, 3, 1536);
        let want = reference_forward(&model, &a_hat, &x);

        let dir = TempDir::new("pipeline-panel");
        let pstore = Arc::new(PanelStore::new(dir.path(), 0).unwrap());
        let cfg = PipelineConfig::staged(StagingConfig::depth(2))
            .with_panel_spill(pstore.clone());
        let mut mem = GpuMem::new(1 << 30);
        let (got, rep) = model.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(2), &cfg).unwrap();
        assert_eq!(got, want, "panel-spilled pass must be byte-identical");
        assert_eq!(mem.used, 0);
        // Two intermediate panels spilled and read back (never the last).
        assert_eq!(pstore.len(), 2);
        assert_eq!(rep.panel_cache_hits + rep.panel_cache_misses, 2);
        assert_eq!(rep.panel_cache_misses, 2, "cacheless panel store reads disk");
        let expect: u64 = (0..2).map(|i| pstore.meta(i).unwrap().file_bytes).sum();
        assert_eq!(rep.panel_spill_bytes, expect);
        assert_eq!(rep.panel_read_bytes, expect);
    }

    #[test]
    fn mmap_staging_with_chunked_panel_spill_is_byte_identical() {
        let mut rng = Pcg::seed(26);
        let a = crate::graphgen::kmer::generate(&mut rng, 240, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(240, 8, (0..240 * 8).map(|_| rng.normal() as f32).collect());
        let model = test_model(&mut rng, 8, 3, 1536);
        let want = reference_forward(&model, &a_hat, &x);

        let segs = crate::partition::robw::robw_partition(&a_hat, 1536);
        let sdir = TempDir::new("pipeline-mmap-seg");
        let pdir = TempDir::new("pipeline-mmap-panel");
        for enc in [
            crate::sparse::segio::SegEncoding::Raw,
            crate::sparse::segio::SegEncoding::Packed,
        ] {
            let store = Arc::new(
                SegmentStore::open_or_spill_encoded(&a_hat, &segs, sdir.path(), 0, enc)
                    .unwrap(),
            );
            let pstore = Arc::new(PanelStore::new(pdir.path(), 0).unwrap());
            let cfg = PipelineConfig::staged(
                StagingConfig::disk(store.clone(), 2).with_mmap(true),
            )
            .with_panel_spill(pstore.clone());
            let mut mem = GpuMem::new(1 << 30);
            let (got, rep) =
                model.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(2), &cfg).unwrap();
            assert_eq!(got, want, "mmap pass ({enc}) must be byte-identical");
            assert_eq!(mem.used, 0);
            // Intermediate panels spilled as per-boundary chunk records
            // and read back through the mapped path.
            assert_eq!(pstore.len(), 2);
            assert_eq!(rep.panel_cache_misses, 2, "mapped panel reads bypass the cache");
            let expect: u64 = (0..2).map(|i| pstore.meta(i).unwrap().file_bytes).sum();
            assert_eq!(rep.panel_spill_bytes, expect);
            assert_eq!(rep.panel_read_bytes, expect);
        }
    }

    #[test]
    fn disk_backed_multilayer_shares_one_store_across_layers() {
        let mut rng = Pcg::seed(23);
        let a = crate::graphgen::kmer::generate(&mut rng, 200, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(200, 8, (0..200 * 8).map(|_| rng.normal() as f32).collect());
        let model = test_model(&mut rng, 8, 2, 1536);
        let want = reference_forward(&model, &a_hat, &x);
        let segs = crate::partition::robw::robw_partition(&a_hat, 1536);
        let dir = TempDir::new("pipeline-disk");
        let unbounded = crate::runtime::segstore::UNBOUNDED_CACHE;
        let store =
            Arc::new(SegmentStore::spill(&a_hat, &segs, dir.path(), unbounded).unwrap());
        let cfg = PipelineConfig::staged(StagingConfig::disk(store, 2));
        let mut mem = GpuMem::new(1 << 30);
        let (got, rep) = model.forward_cpu(&a_hat, &x, &mut mem, &Pool::new(2), &cfg).unwrap();
        assert_eq!(got, want);
        assert_eq!(mem.used, 0);
        // Layer 0 misses to disk; layer 1 re-reads the same segments from
        // the warm host tier.
        assert_eq!(rep.per_layer[0].cache_misses, segs.len());
        assert_eq!(rep.per_layer[1].cache_hits, segs.len());
        assert_eq!(rep.per_layer[1].disk_bytes, 0);
    }

    #[test]
    fn mismatched_budget_disk_pass_fails_before_allocating() {
        let mut rng = Pcg::seed(24);
        let a = crate::graphgen::kmer::generate(&mut rng, 150, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::zeros(150, 8);
        // Layer 1 plans under a different budget than the store was
        // spilled with: the plan check must fail before any allocation.
        let l0 = test_layer(&mut rng, 8, 8, 1024);
        let l1 = OocGcnLayer { seg_budget: 2048, ..test_layer(&mut rng, 8, 8, 1024) };
        let model = OocGcnModel::new(vec![l0, l1]).unwrap();
        let segs = crate::partition::robw::robw_partition(&a_hat, 1024);
        let dir = TempDir::new("pipeline-mismatch");
        let store = Arc::new(SegmentStore::spill(&a_hat, &segs, dir.path(), 0).unwrap());
        let cfg = PipelineConfig::staged(StagingConfig::disk(store, 1));
        let mut mem = GpuMem::new(1 << 30);
        let err =
            model.forward_cpu(&a_hat, &x, &mut mem, &Pool::serial(), &cfg).unwrap_err();
        assert!(err.to_string().contains("layer 1"), "{err}");
        assert!(err.to_string().contains("does not match the RoBW plan"), "{err}");
        assert_eq!(mem.used, 0, "plan guard fires before any allocation");
    }

    #[test]
    fn midstream_panel_oom_balances_the_ledger() {
        let mut rng = Pcg::seed(25);
        let a = crate::graphgen::kmer::generate(&mut rng, 120, 3.0);
        let a_hat = normalize_adjacency(&a);
        let x = Dense::from_vec(120, 4, (0..120 * 4).map(|_| rng.normal() as f32).collect());
        // Layer 1 widens 4 -> 16: its panel cannot fit a ledger sized for
        // layer 0 plus headroom, so the pass aborts at the boundary.
        let l0 = test_layer(&mut rng, 4, 16, 1024);
        let l1 = test_layer(&mut rng, 16, 16, 1024);
        let model = OocGcnModel::new(vec![l0, l1]).unwrap();
        let panel0 = (120 * 4 * 4) as u64;
        let mut mem = GpuMem::new(panel0 + 2048);
        let err = model
            .forward_cpu(
                &a_hat,
                &x,
                &mut mem,
                &Pool::serial(),
                &PipelineConfig::staged(StagingConfig::depth(1)),
            )
            .unwrap_err();
        assert!(
            err.to_string().contains("does not fit"),
            "expected an OOM at the layer boundary: {err}"
        );
        assert_eq!(mem.used, 0, "abort must return panels and segments to the ledger");
    }
}
