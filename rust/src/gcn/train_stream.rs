//! Streamed out-of-core training: the backward pass as a second traversal
//! of the concatenated RoBW plan, run *in reverse* through the same
//! prefetch pipeline as the forward — AIRES's Phase II/III dual-way
//! transfer idea applied to gradients.
//!
//! The forward pass runs through [`forward_pipelined`] with a panel store
//! attached, so every intermediate activation H_l spills to the tiered
//! store instead of staying resident. The backward pass then walks the
//! layers top-down; for layer `l` (input width `f`, output width `h`,
//! aggregated input `agg_l = Â·X_l`, output `H_l = act(agg_l·W_l + b_l)`):
//!
//! * `dZ_l` — the upstream gradient: the softmax-xent gradient at the top
//!   layer, otherwise the dX panel the layer above spilled — masked by
//!   `H_l > 0` when the layer applies ReLU;
//! * `dW_l = agg_lᵀ · dZ_l`, `db_l = colsum(dZ_l)`;
//! * `dX_l = Âᵀ · (dZ_l · W_lᵀ)` (the scatter-free
//!   [`spmm_transpose_view_par_into`] form), spilled through the panel store as
//!   the next layer's `dZ` — gradients never accumulate in host RAM across
//!   layers, just as activations never do in the forward.
//!
//! `dW_l` needs `agg_l`, which the forward consumed. Two policies, the
//! **recompute-vs-reload** choice ([`RecomputePolicy`]):
//!
//! * **Reload** — the forward's finish hook spills each `agg_l` to the
//!   panel store; the backward reads it back at the layer close and does
//!   one whole-matrix `add_at_b`. No backward SpMM work, one extra panel
//!   of I/O per layer. The right choice when staging is cheap.
//! * **Recompute** — the backward re-streams layer `l`'s RoBW segments and
//!   recomputes each segment's `agg` rows from `Â_seg · X_l` into a
//!   bounded scratch, accumulating `dW` segment-wise. No `agg` spill or
//!   reload I/O at all — the choice when I/O is the bottleneck.
//! * **Auto** resolves deterministically from the staging configuration:
//!   a charged I/O cost model marks staging as the bottleneck →
//!   Recompute; otherwise staging is cheap → Reload.
//!
//! Both policies are **byte-identical** to the dense CPU oracle
//! ([`dense_gradients`] / [`dense_step_oracle`]) at every prefetch depth,
//! thread count, backing, and recycle mode (`rust/tests/differential.rs`):
//! segment-wise `dW` accumulation visits rows in the same ascending order
//! as the whole-matrix product, the owner-scans-all transpose kernel gives
//! every `dX` element its additions in the same global row order as the
//! serial scatter, recomputed `agg` rows are bitwise the forward's rows
//! (same segment, same input panel, per-row-independent kernel), and panel
//! round-trips preserve raw f32 bit patterns. Loss arithmetic is shared
//! ([`softmax_xent_grad`] is operation-for-operation the
//! [`softmax_xent`](crate::gcn::model::softmax_xent) sum), as is the SGD
//! update ([`sgd_apply`]), so losses *and* parameters stay bitwise equal
//! to the oracle across steps.
//!
//! Backward overlap mirrors the forward: while the calling thread combines
//! layer `l`'s gradients (its `add_at_b` / transpose scatter / SGD apply),
//! the producer is already staging layer `l−1`'s segments — layer L's
//! backward overlaps layer L−1's gradient combine, under one
//! [`run_recycling`](crate::runtime::prefetch::Prefetch::run_recycling)
//! pipeline whose scratch buffers flow back through the recycle pool
//! (steady-state constant-alloc, `rust/tests/alloc_free.rs`).

use crate::gcn::checkpoint::Checkpoint;
use crate::gcn::model::{
    add_at_b, column_sums_into, dense_affine, matmul_bt_into, softmax_xent, softmax_xent_grad,
};
use crate::gcn::oocgcn::{OocGcnLayer, StagingBacking, StagingConfig};
use crate::gcn::pipeline::{forward_pipelined, layer_widths, PipelineConfig, PipelineReport};
use crate::memsim::{GpuMem, Op, StagingMeter};
use crate::partition::robw::{materialize_into, robw_partition_par, RobwSegment};
use crate::runtime::chaos::FaultPlan;
use crate::runtime::heal::{
    read_panel_healing, read_segment_healing, HealPolicy, HealStats, RebuildSource,
};
use crate::runtime::pool::Pool;
use crate::runtime::recycle::BufferPool;
use crate::runtime::segstore::{PanelRead, PanelSrc, PanelStore, SegmentRead};
use crate::sparse::spmm::{
    spmm, spmm_transpose, spmm_transpose_view_par_into, spmm_view_par_into, Dense, RowSrc,
};
use crate::sparse::Csr;
use crate::util::rng::Pcg;
use anyhow::{anyhow, bail, Result};
use std::str::FromStr;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

/// How the backward pass obtains each layer's aggregated input `agg_l`
/// (needed for `dW_l = agg_lᵀ·dZ_l`) after the forward consumed it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RecomputePolicy {
    /// Spill every `agg_l` during the forward and reload it at the
    /// layer's backward close — cheap when staging is cheap.
    Reload,
    /// Recompute `agg` rows segment-by-segment from the spilled input
    /// activations — no `agg` I/O at all, for I/O-bound passes.
    Recompute,
    /// Resolve from the staging configuration: a charged I/O cost model
    /// means staging is the bottleneck → [`Self::Recompute`]; otherwise
    /// staging is cheap → [`Self::Reload`]. Deterministic — the same
    /// configuration always resolves the same way.
    Auto,
}

impl RecomputePolicy {
    /// Resolve [`Self::Auto`] against a staging configuration; the
    /// explicit policies resolve to themselves.
    pub fn resolve(self, staging: &StagingConfig) -> RecomputePolicy {
        match self {
            RecomputePolicy::Auto => {
                if staging.io_cost.is_some() {
                    RecomputePolicy::Recompute
                } else {
                    RecomputePolicy::Reload
                }
            }
            explicit => explicit,
        }
    }

    /// CLI-facing name.
    pub fn as_str(self) -> &'static str {
        match self {
            RecomputePolicy::Reload => "reload",
            RecomputePolicy::Recompute => "recompute",
            RecomputePolicy::Auto => "auto",
        }
    }
}

impl FromStr for RecomputePolicy {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> Result<RecomputePolicy> {
        match s {
            "reload" => Ok(RecomputePolicy::Reload),
            "recompute" => Ok(RecomputePolicy::Recompute),
            "auto" => Ok(RecomputePolicy::Auto),
            other => bail!("unknown recompute policy {other:?} (reload|recompute|auto)"),
        }
    }
}

impl std::fmt::Display for RecomputePolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

/// Configuration of one streamed training step.
#[derive(Clone)]
pub struct TrainStreamConfig {
    /// Phase II staging (depth, backing, I/O cost, recycle pool), shared
    /// by the forward and backward traversals.
    pub staging: StagingConfig,
    /// The tiered panel store activations, aggregated inputs, and
    /// gradient panels stream through. Always required — streamed
    /// training is out-of-core by construction.
    pub panels: Arc<PanelStore>,
    /// Recompute-vs-reload policy for aggregated inputs.
    pub policy: RecomputePolicy,
}

impl TrainStreamConfig {
    /// Build with the [`RecomputePolicy::Auto`] policy.
    pub fn new(staging: StagingConfig, panels: Arc<PanelStore>) -> TrainStreamConfig {
        TrainStreamConfig { staging, panels, policy: RecomputePolicy::Auto }
    }

    /// The same configuration with an explicit policy.
    pub fn with_policy(mut self, policy: RecomputePolicy) -> TrainStreamConfig {
        self.policy = policy;
        self
    }
}

/// Panel-store slot layout of one streamed step for an `nl`-layer model.
/// Activation slots `0..nl-1` are written by the forward engine's own
/// panel spilling (layer `l`'s output H_l at slot `l`, never the last
/// layer's); aggregated inputs live above them; one rotating slot carries
/// the dX hand-off between adjacent backward layers (safe to reuse
/// because backward consumption is strictly layer-ordered).
fn agg_slot(nl: usize, l: usize) -> usize {
    nl + l
}

fn grad_slot(nl: usize) -> usize {
    2 * nl
}

/// Report of one streamed training step.
#[derive(Debug, Clone)]
pub struct StepReport {
    /// Softmax-xent loss of the step (before the SGD update) — bitwise
    /// the dense oracle's loss.
    pub loss: f32,
    /// The policy the step actually ran ([`RecomputePolicy::Auto`]
    /// resolved).
    pub policy: RecomputePolicy,
    /// The forward traversal's pipeline report.
    pub forward: PipelineReport,
    /// Segments the backward traversal streamed (layer 0 streams none
    /// under [`RecomputePolicy::Reload`] — its `dW` is one whole-matrix
    /// product off the reloaded panel).
    pub backward_segments: usize,
    /// Bytes of aggregated-input panels spilled during the forward
    /// (Reload only).
    pub agg_spill_bytes: u64,
    /// Bytes of aggregated-input panels read back from disk (Reload only;
    /// host-tier hits add nothing).
    pub agg_read_bytes: u64,
    /// Bytes of gradient (dX) panels spilled between backward layers.
    pub grad_spill_bytes: u64,
    /// Bytes of gradient panels read back from disk.
    pub grad_read_bytes: u64,
    /// Bytes of activation panels read back from disk for ReLU masks and
    /// recompute inputs.
    pub act_read_bytes: u64,
    /// Backward panel reads served by the panel store's host cache.
    pub backward_panel_hits: usize,
    /// Backward panel reads that went to disk.
    pub backward_panel_misses: usize,
    /// Measured adjacency bytes the backward traversal read from the NVMe
    /// tier (disk backing only).
    pub backward_disk_bytes: u64,
    /// Backward segment reads served by the segment store's host cache.
    pub backward_cache_hits: usize,
    /// Backward segment reads that went to disk.
    pub backward_cache_misses: usize,
    /// Ledger high-water mark over the whole step (forward + backward).
    pub peak_gpu_bytes: u64,
    /// Recovery counters over the whole step (forward + backward, segment
    /// + panel reads) — the only field allowed to differ from a fault-free
    /// run of the same step.
    pub heal: HealStats,
}

/// Apply one SGD update in place: `W -= lr·dW`, `b -= lr·db`. Shared by
/// the streamed trainer and the dense oracle so parameters stay bitwise
/// equal between them.
pub fn sgd_apply(layer: &mut OocGcnLayer, dw: &Dense, db: &[f32], lr: f32) {
    assert_eq!((layer.w.nrows, layer.w.ncols), (dw.nrows, dw.ncols), "dW shape mismatch");
    assert_eq!(layer.b.len(), db.len(), "db shape mismatch");
    for (w, &g) in layer.w.data.iter_mut().zip(dw.data.iter()) {
        *w -= lr * g;
    }
    for (b, &g) in layer.b.iter_mut().zip(db.iter()) {
        *b -= lr * g;
    }
}

/// Zero `dz` wherever the layer's forward output `h` is non-positive —
/// the ReLU backward mask (`H > 0 ⇔` pre-activation `> 0`; exact zeros
/// mask, matching the forward's `max(z, 0)`).
fn mask_relu(dz: &mut Dense, h: &Dense) {
    debug_assert_eq!((dz.nrows, dz.ncols), (h.nrows, h.ncols));
    for (d, &v) in dz.data.iter_mut().zip(h.data.iter()) {
        if v <= 0.0 {
            *d = 0.0;
        }
    }
}

/// Learnable synthetic labels for a feature matrix: random projection of
/// the features, quantile-split into `classes` — the same scheme the
/// artifact-backed [`Trainer`](crate::gcn::train::Trainer) uses, factored
/// out so the streamed CLI path can train without artifacts.
pub fn synthetic_labels(x: &Dense, classes: usize, rng: &mut Pcg) -> Vec<i32> {
    let (n, f0) = (x.nrows, x.ncols);
    assert!(classes > 0, "need at least one class");
    if n == 0 {
        return Vec::new();
    }
    let proj: Vec<f32> = (0..f0).map(|_| rng.normal() as f32).collect();
    let scores: Vec<f32> = (0..n)
        .map(|i| x.row(i).iter().zip(proj.iter()).map(|(&a, &b)| a * b).sum())
        .collect();
    let mut sorted = scores.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    scores
        .iter()
        .map(|s| {
            let rank = sorted.partition_point(|&v| v < *s);
            ((rank * classes / n).min(classes - 1)) as i32
        })
        .collect()
}

/// Poison-tolerant ledger lock (same rationale as the forward engine's:
/// surface the original worker panic, not a secondary `PoisonError`).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Ledger state shared between the backward staging producer and the
/// consumer: staged segment bytes, the current layer's working-set bytes,
/// and the traversal's measured-I/O meter.
struct BackLedger<'a> {
    mem: &'a mut GpuMem,
    /// Staged segment bytes not yet freed by a consume.
    staged: u64,
    /// Backward working-set bytes charged at layer opens, freed at closes.
    work: u64,
    meter: StagingMeter,
    /// Recovery counters from the staging producer — accumulated under the
    /// ledger lock (the producer closure is `Fn`), kept separate from the
    /// meter so oracle comparisons stay exact.
    heal: HealStats,
}

/// The backward pass's view of layer `l`'s input activations X_l
/// (recompute policy only).
enum XInput<'a> {
    /// The caller's features (layer 0).
    Borrowed(&'a Dense),
    /// A spilled activation panel read back owned.
    Owned(Dense),
    /// A spilled activation panel served shared from the host tier.
    Shared(Arc<Dense>),
}

impl XInput<'_> {
    fn panel(&self) -> &Dense {
        match self {
            XInput::Borrowed(p) => p,
            XInput::Owned(p) => p,
            XInput::Shared(p) => p,
        }
    }

    fn retire(self, recycle: Option<&BufferPool>) {
        if let XInput::Owned(p) = self {
            if let Some(rp) = recycle {
                rp.put_panel(p.data);
            }
        }
    }
}

/// Consumer-side state of one backward traversal. A struct (rather than
/// captured locals) so `open_layer`/`segment`/`close_layer` can borrow
/// disjoint fields without fighting the closure borrow checker, and so an
/// abort can [`Self::reclaim`] every live slab in one place.
struct BackwardPass<'a> {
    layers: &'a mut [OocGcnLayer],
    plans: &'a [Vec<RobwSegment>],
    widths: &'a [usize],
    n: usize,
    x0: &'a Dense,
    logits: &'a Dense,
    /// The softmax-xent gradient, taken at the top layer's open.
    grad_out: Option<Dense>,
    panels: &'a PanelStore,
    recycle: Option<&'a BufferPool>,
    pool: &'a Pool,
    recompute: bool,
    lr: f32,
    /// Recovery policy for the pass's own panel reads (the staging
    /// producer carries its own copy through the ledger).
    policy: &'a HealPolicy,
    chaos: Option<&'a FaultPlan>,
    // ---- live per-layer state (Some between open and close).
    dz: Option<Dense>,
    dagg: Option<Vec<f32>>,
    dx: Option<Dense>,
    xl: Option<XInput<'a>>,
    scratch: Option<Vec<f32>>,
    dw: Option<Dense>,
    /// Working-set bytes currently charged on the ledger for this layer.
    work: u64,
    // ---- traversal counters.
    grad_spill_bytes: u64,
    grad_read_bytes: u64,
    agg_read_bytes: u64,
    act_read_bytes: u64,
    panel_hits: usize,
    panel_misses: usize,
    /// Recovery counters from the pass's panel reads.
    heal: HealStats,
}

impl<'a> BackwardPass<'a> {
    fn zeroed(&self, len: usize) -> Vec<f32> {
        match self.recycle {
            Some(rp) => rp.take_panel(len),
            None => vec![0f32; len],
        }
    }

    fn retire_vec(&self, v: Vec<f32>) {
        if let Some(rp) = self.recycle {
            rp.put_panel(v);
        }
    }

    fn retire_read(&self, pr: PanelRead) {
        if let PanelRead::Owned(p) = pr {
            if let Some(rp) = self.recycle {
                rp.put_panel(p.data);
            }
        }
    }

    /// Turn a panel read into an owned, mutable `Dense` (the dZ panel is
    /// masked and consumed in place; a cache-shared panel is copied into
    /// recycled scratch rather than mutated under the host tier).
    fn owned_panel(&self, pr: PanelRead) -> Dense {
        match pr {
            PanelRead::Owned(p) => p,
            PanelRead::Mapped(m) => m.to_dense(),
            PanelRead::Shared(p) => {
                let mut v = match self.recycle {
                    Some(rp) => rp.take_panel_scratch(p.data.len()),
                    None => Vec::with_capacity(p.data.len()),
                };
                v.extend_from_slice(&p.data);
                Dense::from_vec(p.nrows, p.ncols, v)
            }
        }
    }

    fn note_panel(&mut self, cache_hit: bool) {
        if cache_hit {
            self.panel_hits += 1;
        } else {
            self.panel_misses += 1;
        }
    }

    /// Layer open: charge the layer's backward working set, materialize
    /// dZ (softmax gradient at the top, spilled dX below), apply the ReLU
    /// mask, and precompute `dAgg = dZ·Wᵀ` plus the recompute-policy
    /// residents.
    fn open_layer(&mut self, l: usize, ledger: &Mutex<BackLedger>) -> Result<()> {
        let nl = self.layers.len();
        let n = self.n;
        let (f, h) = (self.widths[l], self.layers[l].w.ncols);
        let max_seg_rows = self.plans[l].iter().map(|s| s.row_hi - s.row_lo).max().unwrap_or(0);
        // dZ, plus (inner layers) dAgg and the dX accumulator, plus
        // (recompute) the dW accumulator, the per-segment aggregation
        // scratch, and the resident input panel.
        let mut work = (n * h * 4) as u64;
        if l > 0 {
            work += 2 * (n * f * 4) as u64;
        }
        if self.recompute {
            work += ((f * h + max_seg_rows * f + n * f) * 4) as u64;
        }
        {
            let mut led = lock(ledger);
            led.mem
                .alloc(work, "backward working set")
                .map_err(|e| anyhow!("backward layer {l}: working set does not fit: {e}"))?;
            led.work += work;
        }
        self.work = work;

        let mut dz = if l + 1 == nl {
            self.grad_out.take().expect("softmax gradient present at top-layer open")
        } else {
            // Backward panel reads stay on the copying path even under
            // `staging.mmap`: every consumer either mutates the panel in
            // place (dZ masking) or needs one contiguous slab (`add_at_b`),
            // so a mapping would be materialized immediately anyway.
            let (pr, origin) = read_panel_healing(
                self.panels,
                grad_slot(nl),
                self.recycle,
                false,
                self.policy,
                self.chaos,
                &mut self.heal,
            )
            .map_err(|e| anyhow!("backward layer {l}: reading spilled gradient panel: {e}"))?;
            self.grad_read_bytes += origin.disk_bytes;
            self.note_panel(origin.cache_hit);
            self.owned_panel(pr)
        };
        debug_assert_eq!((dz.nrows, dz.ncols), (n, h));

        if self.layers[l].relu {
            if l + 1 == nl {
                mask_relu(&mut dz, self.logits);
            } else {
                // The mask panel is resident only for the mask itself.
                let mask_bytes = (n * h * 4) as u64;
                {
                    let mut led = lock(ledger);
                    led.mem.alloc(mask_bytes, "relu mask panel").map_err(|e| {
                        anyhow!("backward layer {l}: mask panel does not fit: {e}")
                    })?;
                    led.work += mask_bytes;
                }
                self.work += mask_bytes;
                let (pr, origin) = read_panel_healing(
                    self.panels,
                    l,
                    self.recycle,
                    false,
                    self.policy,
                    self.chaos,
                    &mut self.heal,
                )
                .map_err(|e| {
                    anyhow!("backward layer {l}: reading spilled activation panel: {e}")
                })?;
                self.act_read_bytes += origin.disk_bytes;
                self.note_panel(origin.cache_hit);
                mask_relu(&mut dz, &pr);
                self.retire_read(pr);
                {
                    let mut led = lock(ledger);
                    led.mem.free(mask_bytes);
                    led.work -= mask_bytes;
                }
                self.work -= mask_bytes;
            }
        }

        if l > 0 {
            let mut dagg = self.zeroed(n * f);
            matmul_bt_into(&dz, &self.layers[l].w, self.pool, &mut dagg);
            self.dagg = Some(dagg);
            self.dx = Some(Dense::from_vec(n, f, self.zeroed(n * f)));
        }
        if self.recompute {
            self.dw = Some(Dense::from_vec(f, h, self.zeroed(f * h)));
            self.scratch = Some(self.zeroed(max_seg_rows * f));
            self.xl = Some(if l == 0 {
                XInput::Borrowed(self.x0)
            } else {
                let (pr, origin) = read_panel_healing(
                    self.panels,
                    l - 1,
                    self.recycle,
                    false,
                    self.policy,
                    self.chaos,
                    &mut self.heal,
                )
                .map_err(|e| anyhow!("backward layer {l}: reading spilled input panel: {e}"))?;
                self.act_read_bytes += origin.disk_bytes;
                self.note_panel(origin.cache_hit);
                match pr {
                    PanelRead::Owned(p) => XInput::Owned(p),
                    PanelRead::Shared(p) => XInput::Shared(p),
                    PanelRead::Mapped(m) => XInput::Owned(m.to_dense()),
                }
            });
        }
        self.dz = Some(dz);
        Ok(())
    }

    /// One streamed backward segment: under recompute, re-derive the
    /// segment's `agg` rows (bitwise the forward's — same sub-matrix, same
    /// input panel, per-row-independent kernel) and fold them into `dW`;
    /// for inner layers, scatter the segment's `dAgg` rows into the `dX`
    /// accumulator through the deterministic owner-scans-all transpose.
    fn segment(&mut self, l: usize, i: usize, sub: &SegmentRead) -> Result<()> {
        let seg = &self.plans[l][i];
        let (lo, hi) = (seg.row_lo, seg.row_hi);
        let rows = hi - lo;
        let f = self.widths[l];
        let h = self.layers[l].w.ncols;
        // View-based kernels: a mapped segment read (`staging.mmap`) never
        // materializes — both products run straight off the page cache.
        let view = sub.view();
        if self.recompute {
            let scratch = self.scratch.as_mut().expect("recompute scratch live at segment");
            let xl = self.xl.as_ref().expect("recompute input panel live at segment");
            spmm_view_par_into(view, xl.panel(), self.pool, &mut scratch[..rows * f]);
            let dz = self.dz.as_ref().expect("dZ live at segment");
            let dw = self.dw.as_mut().expect("dW accumulator live at segment");
            add_at_b(dw, &scratch[..rows * f], &dz.data[lo * h..hi * h], rows, self.pool);
        }
        if l > 0 {
            let dagg = self.dagg.as_ref().expect("dAgg live at segment");
            let dx = self.dx.as_mut().expect("dX accumulator live at segment");
            spmm_transpose_view_par_into(view, &dagg[lo * f..hi * f], f, self.pool, &mut dx.data);
        }
        Ok(())
    }

    /// Layer close: finish `dW` (reloading the spilled aggregated input
    /// under the reload policy), reduce `db`, apply SGD, spill `dX` as the
    /// next layer's dZ, and retire every slab to the recycle pool.
    fn close_layer(&mut self, l: usize, ledger: &Mutex<BackLedger>) -> Result<()> {
        let nl = self.layers.len();
        let n = self.n;
        let (f, h) = (self.widths[l], self.layers[l].w.ncols);
        let dz = self.dz.take().expect("dZ present at layer close");
        let dw = if self.recompute {
            self.dw.take().expect("dW accumulator present at layer close")
        } else {
            let agg_bytes = (n * f * 4) as u64;
            {
                let mut led = lock(ledger);
                led.mem.alloc(agg_bytes, "reloaded aggregation panel").map_err(|e| {
                    anyhow!("backward layer {l}: reloaded panel does not fit: {e}")
                })?;
                led.work += agg_bytes;
            }
            self.work += agg_bytes;
            let (pr, origin) = read_panel_healing(
                self.panels,
                agg_slot(nl, l),
                self.recycle,
                false,
                self.policy,
                self.chaos,
                &mut self.heal,
            )
            .map_err(|e| anyhow!("backward layer {l}: reloading aggregated input: {e}"))?;
            self.agg_read_bytes += origin.disk_bytes;
            self.note_panel(origin.cache_hit);
            let mut dw = Dense::from_vec(f, h, self.zeroed(f * h));
            // Whole-matrix product: same per-element row order as the
            // segment-wise accumulation, so both policies match bitwise.
            add_at_b(&mut dw, &pr.data, &dz.data, n, self.pool);
            self.retire_read(pr);
            dw
        };
        let mut db = self.zeroed(h);
        column_sums_into(&dz, &mut db);
        sgd_apply(&mut self.layers[l], &dw, &db, self.lr);
        if l > 0 {
            let dx = self.dx.take().expect("dX accumulator present at layer close");
            let bytes = self.panels.put(grad_slot(nl), &dx).map_err(|e| {
                anyhow!("backward layer {l}: spilling gradient panel: {e}")
            })?;
            self.grad_spill_bytes += bytes;
            self.retire_vec(dx.data);
            if let Some(dagg) = self.dagg.take() {
                self.retire_vec(dagg);
            }
        }
        self.retire_vec(dz.data);
        self.retire_vec(dw.data);
        self.retire_vec(db);
        if let Some(s) = self.scratch.take() {
            self.retire_vec(s);
        }
        if let Some(x) = self.xl.take() {
            x.retire(self.recycle);
        }
        {
            let mut led = lock(ledger);
            led.mem.free(self.work);
            led.work -= self.work;
        }
        self.work = 0;
        Ok(())
    }

    /// Retire every live slab — the abort path's cleanup (idempotent; a
    /// successful traversal has already taken everything).
    fn reclaim(&mut self) {
        if let Some(d) = self.dz.take() {
            self.retire_vec(d.data);
        }
        if let Some(v) = self.dagg.take() {
            self.retire_vec(v);
        }
        if let Some(d) = self.dx.take() {
            self.retire_vec(d.data);
        }
        if let Some(d) = self.dw.take() {
            self.retire_vec(d.data);
        }
        if let Some(v) = self.scratch.take() {
            self.retire_vec(v);
        }
        if let Some(x) = self.xl.take() {
            x.retire(self.recycle);
        }
        self.grad_out = None;
    }
}

/// Out-of-core trainer: owns the parameter state and streams both
/// traversals of every step through the tiered stores. The dense-artifact
/// [`Trainer`](crate::gcn::train::Trainer) is this path's oracle, not a
/// dependency — no PJRT artifact is touched here.
pub struct StreamedTrainer {
    /// The model parameters, updated in place each step.
    pub layers: Vec<OocGcnLayer>,
    labels: Vec<i32>,
    /// Loss per completed step — bitwise the dense oracle's losses.
    pub losses: Vec<f32>,
}

impl StreamedTrainer {
    /// Build a trainer, validating the width chain and the label range.
    pub fn new(layers: Vec<OocGcnLayer>, labels: Vec<i32>) -> Result<StreamedTrainer> {
        if layers.is_empty() {
            bail!("a streamed trainer needs at least one layer");
        }
        for (l, w) in layers.windows(2).enumerate() {
            if w[0].w.ncols != w[1].w.nrows {
                bail!(
                    "layer {l} outputs width {} but layer {} expects width {}",
                    w[0].w.ncols,
                    l + 1,
                    w[1].w.nrows
                );
            }
        }
        let classes = layers.last().expect("non-empty").w.ncols;
        if let Some(&y) = labels.iter().find(|&&y| y < 0 || y as usize >= classes) {
            bail!("label {y} out of range for {classes} classes");
        }
        Ok(StreamedTrainer { layers, labels, losses: Vec::new() })
    }

    /// One streamed SGD step: pipelined forward (activations — and, under
    /// reload, aggregated inputs — spilling through the panel store),
    /// softmax-xent loss, then the streamed backward traversal in reverse
    /// layer order. Returns the step's report; the loss is also appended
    /// to [`Self::losses`].
    pub fn step(
        &mut self,
        a_hat: &Csr,
        x0: &Dense,
        mem: &mut GpuMem,
        pool: &Pool,
        cfg: &TrainStreamConfig,
        lr: f32,
    ) -> Result<StepReport> {
        let nl = self.layers.len();
        let n = a_hat.nrows;
        if n == 0 {
            bail!("streamed training needs a non-empty graph");
        }
        if x0.nrows != n {
            bail!("features have {} rows but the graph has {n} nodes", x0.nrows);
        }
        if self.labels.len() != n {
            bail!("{} labels for {n} nodes", self.labels.len());
        }
        let widths = layer_widths(&self.layers, x0.ncols)?;
        let resolved = cfg.policy.resolve(&cfg.staging);
        let recompute = resolved == RecomputePolicy::Recompute;
        let staging = &cfg.staging;
        let recycle = staging.recycle.as_deref();
        let panels: &PanelStore = &cfg.panels;

        // ---- Forward through the shared cross-layer engine. Under the
        // reload policy the finish hook spills every layer's aggregated
        // input before the combine.
        let pcfg =
            PipelineConfig { staging: staging.clone(), panel_spill: Some(cfg.panels.clone()) };
        let layers = &self.layers;
        let mut agg_spill = 0u64;
        let (logits, forward) = forward_pipelined(
            layers,
            &mut agg_spill,
            a_hat,
            x0,
            mem,
            pool,
            &pcfg,
            &mut |_, _, seg, sub, x_l, agg| {
                let f = x_l.ncols();
                let out = &mut agg.data[seg.row_lo * f..seg.row_hi * f];
                match x_l {
                    PanelSrc::Dense(d) => spmm_view_par_into(sub.view(), d, pool, out),
                    PanelSrc::Mapped(m) => spmm_view_par_into(sub.view(), m, pool, out),
                }
                Ok(())
            },
            &mut |spill: &mut u64, l, agg| {
                if !recompute {
                    *spill += panels.put(agg_slot(nl, l), agg).map_err(|e| {
                        anyhow!("layer {l}: spilling aggregated input: {e}")
                    })?;
                }
                Ok(dense_affine(agg, &layers[l].w, &layers[l].b, layers[l].relu))
            },
        )?;

        let (loss64, grad) = softmax_xent_grad(&logits, &self.labels);

        // ---- Backward plans: same memoization-by-budget as the forward
        // (which already validated any disk manifest against them).
        let mut plans: Vec<Vec<RobwSegment>> = Vec::with_capacity(nl);
        for layer in layers {
            let planned = plans.len();
            match layers[..planned].iter().position(|p| p.seg_budget == layer.seg_budget) {
                Some(prev) => {
                    let plan = plans[prev].clone();
                    plans.push(plan);
                }
                None => plans.push(robw_partition_par(a_hat, layer.seg_budget, pool)),
            }
        }
        // Reverse layer order; under reload, layer 0 streams no segments
        // (its dW is one whole-matrix product off the reloaded panel) and
        // runs as the epilogue instead.
        let mut order: Vec<(usize, usize)> = Vec::new();
        for l in (0..nl).rev() {
            if l > 0 || recompute {
                for i in 0..plans[l].len() {
                    order.push((l, i));
                }
            }
        }
        let (max_rows, max_nnz) = match (&staging.backing, recycle) {
            (StagingBacking::Memory, Some(_)) => (
                plans.iter().flatten().map(|s| s.row_hi - s.row_lo).max().unwrap_or(0),
                plans.iter().flatten().map(|s| s.nnz).max().unwrap_or(0),
            ),
            _ => (0, 0),
        };

        let ledger = Mutex::new(BackLedger {
            mem,
            staged: 0,
            work: 0,
            meter: StagingMeter::default(),
            heal: HealStats::default(),
        });
        let mut bp = BackwardPass {
            layers: &mut self.layers,
            plans: &plans,
            widths: &widths,
            n,
            x0,
            logits: &logits,
            grad_out: Some(grad),
            panels,
            recycle,
            pool,
            recompute,
            lr,
            policy: &staging.heal,
            chaos: staging.chaos.as_deref(),
            dz: None,
            dagg: None,
            dx: None,
            xl: None,
            scratch: None,
            dw: None,
            work: 0,
            grad_spill_bytes: 0,
            grad_read_bytes: 0,
            agg_read_bytes: 0,
            act_read_bytes: 0,
            panel_hits: 0,
            panel_misses: 0,
            heal: HealStats::default(),
        };

        let streamed = staging.prefetch.run_recycling(
            pool,
            order.len(),
            // ---- Producer: stage backward segments in reverse-layer,
            // ascending-row order (the mirror of the forward's roll-on).
            |g: usize, reuse: Option<Csr>| {
                let (l, i) = order[g];
                let seg = &plans[l][i];
                {
                    let mut led = lock(&ledger);
                    led.mem
                        .alloc(seg.bytes, "RoBW segment")
                        .map_err(|e| anyhow!("backward layer {l}: segment does not fit: {e}"))?;
                    led.staged += seg.bytes;
                }
                match &staging.backing {
                    StagingBacking::Memory => {
                        let mut sub = match (reuse, recycle) {
                            (Some(m), _) => m,
                            (None, Some(rp)) => rp.take_csr(max_rows, max_nnz),
                            (None, None) => Csr::empty(0, 0),
                        };
                        materialize_into(a_hat, seg, &mut sub);
                        if let Some(cm) = &staging.io_cost {
                            let dur = cm.transfer_secs(Op::HtoD, seg.bytes);
                            std::thread::sleep(std::time::Duration::from_secs_f64(dur));
                        }
                        Ok(SegmentRead::Owned(sub))
                    }
                    StagingBacking::Disk(store) => {
                        let mut heal = HealStats::default();
                        let res = read_segment_healing(
                            store,
                            i,
                            reuse,
                            recycle,
                            staging.mmap,
                            &staging.heal,
                            staging.chaos.as_deref(),
                            Some(RebuildSource { a: a_hat, seg }),
                            &mut heal,
                        );
                        let mut led = lock(&ledger);
                        led.heal.merge(&heal);
                        let (sub, origin) = res.map_err(|e| {
                            anyhow!("backward layer {l}: staging segment {i} from disk: {e}")
                        })?;
                        led.meter.record(origin.disk_bytes, origin.cache_hit);
                        Ok(sub)
                    }
                }
            },
            // ---- Consumer: layer opens/closes on the strictly ordered
            // calling thread; layer l's combine overlaps layer l-1's
            // staging exactly as in the forward.
            |g: usize, sub: SegmentRead| {
                let (l, i) = order[g];
                if i == 0 {
                    bp.open_layer(l, &ledger)?;
                }
                bp.segment(l, i, &sub)?;
                {
                    let mut led = lock(&ledger);
                    led.mem.free(plans[l][i].bytes);
                    led.staged -= plans[l][i].bytes;
                }
                let give_back = if recycle.is_some() { sub.reclaim() } else { None };
                if i + 1 == plans[l].len() {
                    bp.close_layer(l, &ledger)?;
                }
                Ok(give_back)
            },
        );

        // Reload epilogue: layer 0 streams no segments, so its open/close
        // run here — against the still-live ledger — after the pipeline
        // drains. (A 1-layer reload model does its entire backward here.)
        let mut epilogue_err: Option<anyhow::Error> = None;
        if streamed.is_ok() && !recompute {
            if let Err(e) = bp.open_layer(0, &ledger).and_then(|()| bp.close_layer(0, &ledger)) {
                epilogue_err = Some(e);
            }
        }

        // Reconcile whatever an abort stranded, on every path.
        bp.reclaim();
        let backward_segments = order.len();
        let (grad_spill_bytes, grad_read_bytes) = (bp.grad_spill_bytes, bp.grad_read_bytes);
        let (agg_read_bytes, act_read_bytes) = (bp.agg_read_bytes, bp.act_read_bytes);
        let (panel_hits, panel_misses) = (bp.panel_hits, bp.panel_misses);
        let mut heal = forward.merged().heal;
        heal.merge(&bp.heal);
        let led = ledger.into_inner().unwrap_or_else(PoisonError::into_inner);
        heal.merge(&led.heal);
        if led.staged > 0 {
            led.mem.free(led.staged);
        }
        if led.work > 0 {
            led.mem.free(led.work);
        }
        let peak_gpu_bytes = led.mem.peak;
        let (backward_disk_bytes, backward_cache_hits, backward_cache_misses) =
            (led.meter.disk_bytes, led.meter.cache_hits, led.meter.cache_misses);
        let leftovers = streamed?;
        if let Some(rp) = recycle {
            for m in leftovers {
                rp.put_csr(m);
            }
        }
        if let Some(e) = epilogue_err {
            return Err(e);
        }

        let loss = loss64 as f32;
        self.losses.push(loss);
        Ok(StepReport {
            loss,
            policy: resolved,
            forward,
            backward_segments,
            agg_spill_bytes: agg_spill,
            agg_read_bytes,
            grad_spill_bytes,
            grad_read_bytes,
            act_read_bytes,
            backward_panel_hits: panel_hits,
            backward_panel_misses: panel_misses,
            backward_disk_bytes,
            backward_cache_hits,
            backward_cache_misses,
            peak_gpu_bytes,
            heal,
        })
    }

    /// Run `steps` streamed SGD steps, returning (first, best, last)
    /// losses of this run. `steps == 0` is a typed error — there would be
    /// no losses to report (the guard the artifact-backed
    /// [`Trainer::train`](crate::gcn::train::Trainer::train) shares).
    pub fn train(
        &mut self,
        a_hat: &Csr,
        x0: &Dense,
        mem: &mut GpuMem,
        pool: &Pool,
        cfg: &TrainStreamConfig,
        steps: usize,
        lr: f32,
    ) -> Result<(f32, f32, f32)> {
        if steps == 0 {
            bail!("training needs at least one step");
        }
        for _ in 0..steps {
            self.step(a_hat, x0, mem, pool, cfg, lr)?;
        }
        let first = self.losses[self.losses.len() - steps];
        let best = self.losses[self.losses.len() - steps..]
            .iter()
            .copied()
            .fold(f32::INFINITY, f32::min);
        let last = *self.losses.last().expect("at least one step ran");
        Ok((first, best, last))
    }

    /// Adopt a [`Checkpoint`]'s parameter and loss state, returning the
    /// step index to resume from. The checkpoint must match the model
    /// layer-for-layer in shape; labels and graph are the caller's and are
    /// not checkpointed. After a restore, continuing the run produces
    /// bitwise the same parameters as the uninterrupted run — streamed
    /// steps draw no randomness, so the state swap is the whole resume.
    pub fn restore(&mut self, ck: &Checkpoint) -> Result<u64> {
        if ck.layers.len() != self.layers.len() {
            bail!(
                "checkpoint has {} layers but the model has {}",
                ck.layers.len(),
                self.layers.len()
            );
        }
        for (l, (cur, new)) in self.layers.iter().zip(ck.layers.iter()).enumerate() {
            if (cur.w.nrows, cur.w.ncols, cur.b.len())
                != (new.w.nrows, new.w.ncols, new.b.len())
            {
                bail!(
                    "checkpoint layer {l} is {}x{} (+{} biases) but the model expects {}x{} (+{})",
                    new.w.nrows,
                    new.w.ncols,
                    new.b.len(),
                    cur.w.nrows,
                    cur.w.ncols,
                    cur.b.len()
                );
            }
        }
        self.layers = ck.layers.clone();
        self.losses = ck.losses.clone();
        Ok(ck.step)
    }
}

/// Per-layer parameter gradients of the dense oracle.
pub struct LayerGrads {
    /// `dW = aggᵀ·dZ`.
    pub dw: Dense,
    /// `db = colsum(dZ)`.
    pub db: Vec<f32>,
}

/// Dense CPU gradient oracle: whole-matrix forward keeping every
/// aggregated input and activation in RAM, then the textbook backward
/// chain — using the *same* shared kernels ([`add_at_b`],
/// [`matmul_bt_into`], [`column_sums_into`], [`mask_relu`]) in the same
/// per-element accumulation order as the streamed pass, so gradients are
/// bitwise comparable. Serial by construction (the point of an oracle).
pub fn dense_gradients(
    layers: &[OocGcnLayer],
    a_hat: &Csr,
    x0: &Dense,
    labels: &[i32],
) -> Result<(f64, Vec<LayerGrads>)> {
    let nl = layers.len();
    if nl == 0 {
        bail!("a GCN model needs at least one layer");
    }
    let widths = layer_widths(layers, x0.ncols)?;
    let n = a_hat.nrows;
    let serial = Pool::serial();

    let mut aggs: Vec<Dense> = Vec::with_capacity(nl);
    let mut acts: Vec<Dense> = Vec::with_capacity(nl);
    for l in 0..nl {
        let input = if l == 0 { x0 } else { &acts[l - 1] };
        let agg = spmm(a_hat, input);
        let act = dense_affine(&agg, &layers[l].w, &layers[l].b, layers[l].relu);
        aggs.push(agg);
        acts.push(act);
    }

    let (loss, mut dz) = softmax_xent_grad(&acts[nl - 1], labels);
    let mut grads: Vec<LayerGrads> = Vec::with_capacity(nl);
    for _ in 0..nl {
        grads.push(LayerGrads { dw: Dense::zeros(0, 0), db: Vec::new() });
    }
    for l in (0..nl).rev() {
        if layers[l].relu {
            mask_relu(&mut dz, &acts[l]);
        }
        let h = layers[l].w.ncols;
        let mut dw = Dense::zeros(widths[l], h);
        add_at_b(&mut dw, &aggs[l].data, &dz.data, n, &serial);
        let mut db = vec![0f32; h];
        column_sums_into(&dz, &mut db);
        grads[l] = LayerGrads { dw, db };
        if l > 0 {
            let f = widths[l];
            let mut dagg = vec![0f32; n * f];
            matmul_bt_into(&dz, &layers[l].w, &serial, &mut dagg);
            dz = spmm_transpose(a_hat, &Dense::from_vec(n, f, dagg));
        }
    }
    Ok((loss, grads))
}

/// Dense forward + softmax-xent loss only — the finite-difference probe
/// the gradient checks perturb.
pub fn dense_loss(layers: &[OocGcnLayer], a_hat: &Csr, x0: &Dense, labels: &[i32]) -> Result<f64> {
    let nl = layers.len();
    if nl == 0 {
        bail!("a GCN model needs at least one layer");
    }
    layer_widths(layers, x0.ncols)?;
    let mut cur = None;
    for layer in layers {
        let input = cur.as_ref().unwrap_or(x0);
        let agg = spmm(a_hat, input);
        cur = Some(dense_affine(&agg, &layer.w, &layer.b, layer.relu));
    }
    Ok(softmax_xent(&cur.expect("at least one layer"), labels))
}

/// One dense-oracle SGD step, updating `layers` in place and returning
/// the step's loss. Uses [`sgd_apply`] — the same update arithmetic as
/// the streamed trainer — so oracle and streamed parameters stay bitwise
/// equal step after step.
pub fn dense_step_oracle(
    layers: &mut [OocGcnLayer],
    a_hat: &Csr,
    x0: &Dense,
    labels: &[i32],
    lr: f32,
) -> Result<f32> {
    let (loss, grads) = dense_gradients(layers, a_hat, x0, labels)?;
    for (layer, g) in layers.iter_mut().zip(grads.iter()) {
        sgd_apply(layer, &g.dw, &g.db, lr);
    }
    Ok(loss as f32)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::kmer;
    use crate::sparse::norm::normalize_adjacency;
    use crate::testing::TempDir;

    fn test_layers(rng: &mut Pcg, dims: &[usize], relus: &[bool], budget: u64) -> Vec<OocGcnLayer> {
        assert_eq!(dims.len(), relus.len() + 1);
        dims.windows(2)
            .zip(relus.iter())
            .map(|(w, &relu)| OocGcnLayer {
                w: Dense::from_vec(
                    w[0],
                    w[1],
                    (0..w[0] * w[1]).map(|_| (rng.normal() * 0.3) as f32).collect(),
                ),
                b: (0..w[1]).map(|_| (rng.normal() * 0.1) as f32).collect(),
                relu,
                seg_budget: budget,
            })
            .collect()
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    fn fd_w(
        layers: &mut [OocGcnLayer],
        a_hat: &Csr,
        x0: &Dense,
        y: &[i32],
        l: usize,
        k: usize,
        eps: f32,
    ) -> f64 {
        let orig = layers[l].w.data[k];
        layers[l].w.data[k] = orig + eps;
        let lp = dense_loss(layers, a_hat, x0, y).unwrap();
        layers[l].w.data[k] = orig - eps;
        let lm = dense_loss(layers, a_hat, x0, y).unwrap();
        layers[l].w.data[k] = orig;
        (lp - lm) / (2.0 * eps as f64)
    }

    fn fd_b(
        layers: &mut [OocGcnLayer],
        a_hat: &Csr,
        x0: &Dense,
        y: &[i32],
        l: usize,
        k: usize,
        eps: f32,
    ) -> f64 {
        let orig = layers[l].b[k];
        layers[l].b[k] = orig + eps;
        let lp = dense_loss(layers, a_hat, x0, y).unwrap();
        layers[l].b[k] = orig - eps;
        let lm = dense_loss(layers, a_hat, x0, y).unwrap();
        layers[l].b[k] = orig;
        (lp - lm) / (2.0 * eps as f64)
    }

    #[test]
    fn recompute_policy_parses_and_resolves() {
        for p in [RecomputePolicy::Reload, RecomputePolicy::Recompute, RecomputePolicy::Auto] {
            assert_eq!(p.as_str().parse::<RecomputePolicy>().unwrap(), p);
        }
        assert!("fast".parse::<RecomputePolicy>().is_err());
        let cheap = StagingConfig::depth(2);
        assert_eq!(RecomputePolicy::Auto.resolve(&cheap), RecomputePolicy::Reload);
        let costly = StagingConfig {
            io_cost: Some(crate::memsim::CostModel::default()),
            ..StagingConfig::depth(2)
        };
        assert_eq!(RecomputePolicy::Auto.resolve(&costly), RecomputePolicy::Recompute);
        assert_eq!(RecomputePolicy::Reload.resolve(&costly), RecomputePolicy::Reload);
        assert_eq!(RecomputePolicy::Recompute.resolve(&cheap), RecomputePolicy::Recompute);
    }

    #[test]
    fn finite_difference_validates_linear_gradients() {
        let mut rng = Pcg::seed(70);
        let g = kmer::generate(&mut rng, 20, 2.5);
        let a_hat = normalize_adjacency(&g);
        let x0 = Dense::from_vec(20, 5, (0..20 * 5).map(|_| rng.normal() as f32).collect());
        let mut layers = test_layers(&mut rng, &[5, 6, 4, 3], &[false, false, false], 1024);
        let y: Vec<i32> = (0..20).map(|i| (i % 3) as i32).collect();
        let (_, grads) = dense_gradients(&layers, &a_hat, &x0, &y).unwrap();
        let eps = 1e-2f32;
        for l in 0..layers.len() {
            for k in 0..grads[l].dw.data.len() {
                let got = grads[l].dw.data[k] as f64;
                let fd = fd_w(&mut layers, &a_hat, &x0, &y, l, k, eps);
                assert!(
                    (fd - got).abs() <= 0.02 * got.abs().max(5e-3),
                    "layer {l} dW[{k}]: analytic {got} vs fd {fd}"
                );
            }
            for k in 0..grads[l].db.len() {
                let got = grads[l].db[k] as f64;
                let fd = fd_b(&mut layers, &a_hat, &x0, &y, l, k, eps);
                assert!(
                    (fd - got).abs() <= 0.02 * got.abs().max(5e-3),
                    "layer {l} db[{k}]: analytic {got} vs fd {fd}"
                );
            }
        }
    }

    #[test]
    fn finite_difference_validates_relu_gradients() {
        let mut rng = Pcg::seed(71);
        let g = kmer::generate(&mut rng, 22, 2.5);
        let a_hat = normalize_adjacency(&g);
        let x0 = Dense::from_vec(22, 5, (0..22 * 5).map(|_| rng.normal() as f32).collect());
        let mut layers = test_layers(&mut rng, &[5, 6, 4], &[true, false], 1024);
        let y: Vec<i32> = (0..22).map(|i| (i % 4) as i32).collect();
        let (_, grads) = dense_gradients(&layers, &a_hat, &x0, &y).unwrap();
        // ReLU kinks can sit inside the FD window for a few entries, so
        // allow a small out-of-tolerance fraction instead of per-entry
        // strictness; a systematically wrong backward fails wholesale.
        let eps = 5e-3f32;
        let (mut total, mut bad) = (0usize, 0usize);
        for l in 0..layers.len() {
            for k in 0..grads[l].dw.data.len() {
                let got = grads[l].dw.data[k] as f64;
                let fd = fd_w(&mut layers, &a_hat, &x0, &y, l, k, eps);
                total += 1;
                if (fd - got).abs() > 0.15 * got.abs().max(2e-3) {
                    bad += 1;
                }
            }
            for k in 0..grads[l].db.len() {
                let got = grads[l].db[k] as f64;
                let fd = fd_b(&mut layers, &a_hat, &x0, &y, l, k, eps);
                total += 1;
                if (fd - got).abs() > 0.15 * got.abs().max(2e-3) {
                    bad += 1;
                }
            }
        }
        assert!(bad * 20 <= total, "{bad}/{total} gradient entries out of tolerance");
    }

    #[test]
    fn streamed_step_matches_dense_oracle_bitwise() {
        let mut rng = Pcg::seed(81);
        let g = kmer::generate(&mut rng, 160, 3.0);
        let a_hat = normalize_adjacency(&g);
        let x0 = Dense::from_vec(160, 6, (0..160 * 6).map(|_| rng.normal() as f32).collect());
        let layers = test_layers(&mut rng, &[6, 8, 8, 4], &[true, true, false], 1024);
        let labels: Vec<i32> = (0..160).map(|i| (i % 4) as i32).collect();
        for policy in [RecomputePolicy::Reload, RecomputePolicy::Recompute] {
            let mut oracle = layers.clone();
            let mut tr = StreamedTrainer::new(layers.clone(), labels.clone()).unwrap();
            let dir = TempDir::new("train-stream");
            let panels = Arc::new(PanelStore::new(dir.path(), 0).unwrap());
            let cfg = TrainStreamConfig::new(StagingConfig::depth(2), panels).with_policy(policy);
            let mut mem = GpuMem::new(1 << 30);
            let pool = Pool::new(2);
            for step in 0..2 {
                let want = dense_step_oracle(&mut oracle, &a_hat, &x0, &labels, 0.5).unwrap();
                let rep = tr.step(&a_hat, &x0, &mut mem, &pool, &cfg, 0.5).unwrap();
                assert_eq!(
                    rep.loss.to_bits(),
                    want.to_bits(),
                    "{policy:?} step {step}: {} vs {want}",
                    rep.loss
                );
                assert_eq!(rep.policy, policy);
                assert_eq!(mem.used, 0, "{policy:?} step {step}: ledger must balance");
                assert!(rep.grad_spill_bytes > 0, "inner layers spill gradient panels");
                if policy == RecomputePolicy::Reload {
                    assert!(rep.agg_spill_bytes > 0, "reload spills aggregated inputs");
                    assert!(rep.agg_read_bytes > 0, "reload reads them back");
                    // Layer 0 runs as the epilogue, off the streamed plan.
                    assert_eq!(rep.backward_segments, 2 * rep.forward.per_layer[0].segments);
                } else {
                    assert_eq!(rep.agg_spill_bytes, 0);
                    assert_eq!(rep.agg_read_bytes, 0);
                    assert_eq!(rep.backward_segments, 3 * rep.forward.per_layer[0].segments);
                }
            }
            for (l, (lt, lo)) in tr.layers.iter().zip(oracle.iter()).enumerate() {
                assert_eq!(bits(&lt.w.data), bits(&lo.w.data), "{policy:?} layer {l} weights");
                assert_eq!(bits(&lt.b), bits(&lo.b), "{policy:?} layer {l} biases");
            }
        }
    }

    #[test]
    fn mmap_disk_staged_step_matches_dense_oracle_bitwise() {
        let mut rng = Pcg::seed(86);
        let g = kmer::generate(&mut rng, 140, 3.0);
        let a_hat = normalize_adjacency(&g);
        let x0 = Dense::from_vec(140, 6, (0..140 * 6).map(|_| rng.normal() as f32).collect());
        let layers = test_layers(&mut rng, &[6, 8, 4], &[true, false], 1024);
        let labels: Vec<i32> = (0..140).map(|i| (i % 4) as i32).collect();
        let segs = crate::partition::robw::robw_partition(&a_hat, 1024);
        for policy in [RecomputePolicy::Reload, RecomputePolicy::Recompute] {
            let mut oracle = layers.clone();
            let mut tr = StreamedTrainer::new(layers.clone(), labels.clone()).unwrap();
            let sdir = TempDir::new("train-mmap-seg");
            let pdir = TempDir::new("train-mmap-panel");
            let store = Arc::new(
                crate::runtime::segstore::SegmentStore::open_or_spill_encoded(
                    &a_hat,
                    &segs,
                    sdir.path(),
                    0,
                    crate::sparse::segio::SegEncoding::Auto,
                )
                .unwrap(),
            );
            let panels = Arc::new(PanelStore::new(pdir.path(), 0).unwrap());
            let cfg = TrainStreamConfig::new(
                StagingConfig::disk(store, 2).with_mmap(true),
                panels,
            )
            .with_policy(policy);
            let mut mem = GpuMem::new(1 << 30);
            let pool = Pool::new(2);
            for step in 0..2 {
                let want = dense_step_oracle(&mut oracle, &a_hat, &x0, &labels, 0.5).unwrap();
                let rep = tr.step(&a_hat, &x0, &mut mem, &pool, &cfg, 0.5).unwrap();
                assert_eq!(
                    rep.loss.to_bits(),
                    want.to_bits(),
                    "{policy:?} mmap step {step}"
                );
                assert_eq!(mem.used, 0, "{policy:?} mmap step {step}: ledger must balance");
            }
            for (l, (lt, lo)) in tr.layers.iter().zip(oracle.iter()).enumerate() {
                assert_eq!(bits(&lt.w.data), bits(&lo.w.data), "{policy:?} layer {l} weights");
                assert_eq!(bits(&lt.b), bits(&lo.b), "{policy:?} layer {l} biases");
            }
        }
    }

    #[test]
    fn single_layer_and_train_summary_work() {
        let mut rng = Pcg::seed(82);
        let g = kmer::generate(&mut rng, 60, 2.5);
        let a_hat = normalize_adjacency(&g);
        let x0 = Dense::from_vec(60, 5, (0..60 * 5).map(|_| rng.normal() as f32).collect());
        let layers = test_layers(&mut rng, &[5, 3], &[false], 1024);
        let labels: Vec<i32> = (0..60).map(|i| (i % 3) as i32).collect();
        for policy in [RecomputePolicy::Reload, RecomputePolicy::Recompute] {
            let mut oracle = layers.clone();
            let mut tr = StreamedTrainer::new(layers.clone(), labels.clone()).unwrap();
            let dir = TempDir::new("train-stream-1l");
            let panels = Arc::new(PanelStore::new(dir.path(), 0).unwrap());
            let cfg = TrainStreamConfig::new(StagingConfig::serial(), panels).with_policy(policy);
            let mut mem = GpuMem::new(1 << 30);
            let (first, best, last) =
                tr.train(&a_hat, &x0, &mut mem, &Pool::serial(), &cfg, 4, 1.0).unwrap();
            assert_eq!(mem.used, 0);
            assert_eq!(tr.losses.len(), 4);
            assert!(best <= first && best <= last);
            assert!(last < first, "{policy:?}: loss must decrease: {first} -> {last}");
            let mut want = Vec::new();
            for _ in 0..4 {
                want.push(dense_step_oracle(&mut oracle, &a_hat, &x0, &labels, 1.0).unwrap());
            }
            assert_eq!(bits(&tr.losses), bits(&want), "{policy:?} loss curve");
        }
    }

    #[test]
    fn trainer_rejects_invalid_inputs() {
        let mut rng = Pcg::seed(83);
        let layers = test_layers(&mut rng, &[5, 4, 3], &[true, false], 1024);
        // Label out of range.
        let err = StreamedTrainer::new(layers.clone(), vec![0, 3]).unwrap_err();
        assert!(err.to_string().contains("out of range"), "{err}");
        // Unchained widths.
        let mut broken = layers.clone();
        broken[1].w = Dense::zeros(9, 3);
        let err = StreamedTrainer::new(broken, vec![0]).unwrap_err();
        assert!(err.to_string().contains("layer 0"), "{err}");
        assert!(StreamedTrainer::new(Vec::new(), Vec::new()).is_err());

        // steps == 0 is the Trainer bug this module must not inherit.
        let g = kmer::generate(&mut rng, 30, 2.5);
        let a_hat = normalize_adjacency(&g);
        let x0 = Dense::from_vec(30, 5, (0..30 * 5).map(|_| rng.normal() as f32).collect());
        let labels: Vec<i32> = (0..30).map(|i| (i % 3) as i32).collect();
        let mut tr = StreamedTrainer::new(layers.clone(), labels.clone()).unwrap();
        let dir = TempDir::new("train-stream-inval");
        let panels = Arc::new(PanelStore::new(dir.path(), 0).unwrap());
        let cfg = TrainStreamConfig::new(StagingConfig::serial(), panels);
        let mut mem = GpuMem::new(1 << 30);
        let err =
            tr.train(&a_hat, &x0, &mut mem, &Pool::serial(), &cfg, 0, 1.0).unwrap_err();
        assert!(err.to_string().contains("at least one step"), "{err}");
        // Feature/label row mismatches are typed errors, not panics.
        let short_x = Dense::zeros(29, 5);
        assert!(tr.step(&a_hat, &short_x, &mut mem, &Pool::serial(), &cfg, 1.0).is_err());
        let mut short = StreamedTrainer::new(layers, labels[..29].to_vec()).unwrap();
        assert!(short.step(&a_hat, &x0, &mut mem, &Pool::serial(), &cfg, 1.0).is_err());
        assert_eq!(mem.used, 0);
    }

    #[test]
    fn restore_swaps_state_and_validates_shapes() {
        let mut rng = Pcg::seed(85);
        let layers = test_layers(&mut rng, &[5, 4, 3], &[true, false], 1024);
        let mut tr = StreamedTrainer::new(layers.clone(), vec![0i32; 10]).unwrap();
        let mut ck_layers = layers.clone();
        ck_layers[0].w.data[0] = 9.5;
        let ck = Checkpoint {
            step: 3,
            policy: RecomputePolicy::Auto,
            rng: rng.state(),
            losses: vec![2.0, 1.0, 0.5],
            layers: ck_layers,
        };
        assert_eq!(tr.restore(&ck).unwrap(), 3);
        assert_eq!(tr.layers[0].w.data[0].to_bits(), 9.5f32.to_bits());
        assert_eq!(tr.losses, vec![2.0, 1.0, 0.5]);

        let mut wrong = ck.clone();
        wrong.layers.pop();
        let err = tr.restore(&wrong).unwrap_err();
        assert!(err.to_string().contains("has 1 layers"), "{err}");
        let mut wrong = ck.clone();
        wrong.layers[1].w = Dense::zeros(9, 9);
        let err = tr.restore(&wrong).unwrap_err();
        assert!(err.to_string().contains("layer 1"), "{err}");
    }

    #[test]
    fn synthetic_labels_cover_classes_in_range() {
        let mut rng = Pcg::seed(84);
        let x = Dense::from_vec(64, 6, (0..64 * 6).map(|_| rng.normal() as f32).collect());
        let y = synthetic_labels(&x, 4, &mut rng);
        assert_eq!(y.len(), 64);
        assert!(y.iter().all(|&c| (0..4).contains(&c)));
        for c in 0..4 {
            assert!(y.iter().any(|&v| v == c), "quantile split must hit class {c}");
        }
        assert!(synthetic_labels(&Dense::zeros(0, 3), 4, &mut rng).is_empty());
    }
}
