//! AIRES — Accelerating Out-of-Core GCNs via Algorithm-System Co-Design.
//!
//! Reproduction of Jayakody, Zhao & Wang (ASAP 2025). The library implements
//! the paper's algorithm contribution (RoBW row block-wise alignment +
//! tiling, §III-A), its system contribution (three-phase dynamic scheduling
//! with dual-way GDS/DMA transfers and the Eq. 5-7 dynamic output-memory
//! model, §III-B), all three baselines (MaxMemory, UCG, ETC), and every
//! substrate they sit on: sparse formats, graph generators, a calibrated
//! tiered-memory simulator, a GCN training driver, and a PJRT runtime that
//! executes the AOT-compiled JAX/Pallas artifacts. See DESIGN.md for the
//! module inventory and experiment index.
//!
//! Layering (Python never on the request path):
//! * L1 Pallas kernels + L2 JAX model are compiled once (`make artifacts`)
//!   into `artifacts/*.hlo.txt`;
//! * L3 (this crate) loads them via [`runtime`] and drives everything.
//!
//! See `ARCHITECTURE.md` at the repo root for the paper-to-code map and
//! the module dependency diagram.

#![warn(missing_docs)]

pub mod benchdb;
pub mod benchlib;
pub mod config;
pub mod coordinator;
pub mod gcn;
pub mod graphgen;
pub mod memsim;
pub mod partition;
pub mod runtime;
pub mod sched;
pub mod sparse;
pub mod testing;
pub mod util;

pub use sparse::{Csc, Csr};
