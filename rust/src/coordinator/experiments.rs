//! Experiment harnesses: one function per paper table/figure.
//!
//! Each returns plain row structs so the CLI, benches and EXPERIMENTS.md
//! all render from the same source. Paper artifact -> function:
//!   Fig. 3  -> [`fig3_merging`]      Fig. 6 -> [`fig6_speedup`]
//!   Fig. 7  -> [`fig7_io_breakdown`] Fig. 8 -> [`fig8_bandwidth`]
//!   Fig. 9  -> [`fig9_feature_size`] Tab. III -> [`table3_memcap`]

use crate::graphgen::{DatasetStats, CATALOG};
use crate::memsim::CostModel;
use crate::partition::{naive, robw};
use crate::sched::{all_schedulers, EpochResult, Scheduler, Workload, STATIC_MIN_FRAC};
use crate::sparse::Csr;

/// Paper model config (§V-A): 256-wide features, 99% sparse, 1 GCN layer
/// per epoch cycle pair.
pub const FEAT_DIM: u64 = 256;
/// GCN layers per epoch (see [`FEAT_DIM`]).
pub const LAYERS: u32 = 1;

/// Fixed CPU cost per partial-row boundary in the naive pipeline: CSR
/// fragment merge + re-staging + allocator/driver sync (calibrated to
/// reproduce Fig. 3's overhead magnitudes).
pub const MERGE_FIXED_S: f64 = 0.022;

// ---------------------------------------------------------------------- Fig 3

/// One Fig. 3 bar: merging overhead of the naive (non-aligned) pipeline
/// as a percentage of the SpGEMM computation latency.
#[derive(Debug, Clone)]
pub struct Fig3Row {
    /// Dataset name.
    pub dataset: String,
    /// Segment byte budget left for CSR A after the static reservation.
    pub seg_budget: u64,
    /// Naive segments the budget produces.
    pub n_segments: u64,
    /// Time spent merging partial rows (the Fig. 3 overhead).
    pub merge_secs: f64,
    /// SpGEMM compute time the overhead is normalized against.
    pub compute_secs: f64,
    /// `merge / compute` as a percentage.
    pub overhead_pct: f64,
    /// RoBW alignment removes the overhead entirely (the paper's fix).
    pub robw_overhead_pct: f64,
}

/// Fig. 3: merging overhead for kV2a / kU1a / kP1a at their Table II
/// memory constraints. The naive pipeline cuts A at byte granularity; each
/// boundary's partial row round-trips and the segment is re-staged.
pub fn fig3_merging(cm: &CostModel) -> Vec<Fig3Row> {
    ["kV2a", "kU1a", "kP1a"]
        .iter()
        .map(|name| {
            let d = crate::graphgen::catalog::by_name(name).unwrap();
            let w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
            fig3_row(&w, cm)
        })
        .collect()
}

/// Fig. 3 at an arbitrary memory constraint (used by the ablation bench).
pub fn fig3_row(w: &Workload, cm: &CostModel) -> Fig3Row {
    let a = w.a_bytes();
    // What the static allocator leaves for streaming A.
    let reserved = (w.req_bytes() as f64 * STATIC_MIN_FRAC) as u64;
    let seg_budget = w.gpu_mem_bytes.saturating_sub(reserved).max(64 << 20);
    let n_segments = a.div_ceil(seg_budget).max(1);
    // Per boundary: fixed merge cost + partial tail DtoH + 2x host memcpy
    // + tail resend (the Fig. 3 "merging the partial segments and data
    // transfer between GPU and host memory").
    let tail = (w.avg_row_bytes() / 2.0) as u64;
    let per_boundary = MERGE_FIXED_S
        + cm.transfer_secs(crate::memsim::Op::DtoH, tail)
        + cm.transfer_secs(crate::memsim::Op::HostMemcpy, 2 * tail)
        + cm.transfer_secs(crate::memsim::Op::HtoD, tail);
    let merge_secs = per_boundary * n_segments as f64;
    let compute_secs =
        cm.gpu_secs(w.spgemm_flops(), a + w.b_bytes() + w.c_bytes()) * w.cycles() as f64;
    Fig3Row {
        dataset: w.name.clone(),
        seg_budget,
        n_segments,
        merge_secs: merge_secs * w.cycles() as f64,
        compute_secs,
        overhead_pct: 100.0 * merge_secs * w.cycles() as f64 / compute_secs,
        robw_overhead_pct: 0.0,
    }
}

/// Property cross-check behind Fig. 3 on *materialized* matrices: the real
/// naive partitioner produces partial cuts, the real RoBW partitioner
/// produces none. Returns (naive partial cuts, robw partial nnz mismatch).
pub fn fig3_cross_check(a: &Csr, budget: u64) -> (u64, u64) {
    let naive_cuts = naive::merge_overhead(&naive::naive_partition(a, budget)).partial_cuts;
    let robw_mismatch = robw::robw_partition(a, budget)
        .iter()
        .map(|s| (s.nnz != a.rowptr[s.row_hi] - a.rowptr[s.row_lo]) as u64)
        .sum();
    (naive_cuts, robw_mismatch)
}

// ---------------------------------------------------------------------- Fig 6

/// One dataset's end-to-end epoch results across all four schedulers.
#[derive(Debug, Clone)]
pub struct Fig6Row {
    /// Dataset name.
    pub dataset: String,
    /// One [`EpochResult`] per scheduler, in `all_schedulers` order.
    pub results: Vec<EpochResult>,
}

impl Fig6Row {
    /// Epoch latency of one scheduler (`None` = OOM).
    pub fn makespan(&self, sched: &str) -> Option<f64> {
        self.results.iter().find(|r| r.scheduler == sched).and_then(|r| r.makespan_s)
    }

    /// Speedup of AIRES over `sched` (paper Fig. 6's y-axis).
    pub fn speedup_over(&self, sched: &str) -> Option<f64> {
        Some(self.makespan(sched)? / self.makespan("AIRES")?)
    }
}

/// Fig. 6: per-epoch latency for every catalog dataset x scheduler.
pub fn fig6_speedup(cm: &CostModel) -> Vec<Fig6Row> {
    CATALOG.iter().map(|d| fig6_row(d, cm)).collect()
}

/// One dataset's Fig. 6 row.
pub fn fig6_row(d: &DatasetStats, cm: &CostModel) -> Fig6Row {
    let w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
    Fig6Row {
        dataset: d.name.to_string(),
        results: all_schedulers().iter().map(|s| s.run_epoch(&w, cm)).collect(),
    }
}

// ---------------------------------------------------------------------- Fig 7

/// Fig. 7: GPU-CPU I/O breakdown (bytes + latency per memcpy kind).
#[derive(Debug, Clone)]
pub struct Fig7Row {
    /// Dataset name.
    pub dataset: String,
    /// Scheduler the row measures.
    pub scheduler: &'static str,
    /// Host-to-device bytes.
    pub htod_bytes: u64,
    /// Device-to-host bytes.
    pub dtoh_bytes: u64,
    /// Unified-memory migration bytes.
    pub um_bytes: u64,
    /// Seconds on the H2D engine.
    pub htod_secs: f64,
    /// Seconds on the D2H engine.
    pub dtoh_secs: f64,
    /// Seconds in UM fault handling.
    pub um_secs: f64,
}

/// Fig. 7 rows: per (dataset, scheduler) GPU-CPU traffic breakdown.
pub fn fig7_io_breakdown(cm: &CostModel) -> Vec<Fig7Row> {
    let mut rows = Vec::new();
    for d in CATALOG.iter() {
        let w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
        for s in all_schedulers() {
            let r = s.run_epoch(&w, cm);
            if r.oom.is_some() {
                continue;
            }
            rows.push(Fig7Row {
                dataset: d.name.to_string(),
                scheduler: r.scheduler,
                htod_bytes: r.io.get("HtoD").bytes,
                dtoh_bytes: r.io.get("DtoH").bytes,
                um_bytes: r.io.get("UM").bytes,
                htod_secs: r.io.get("HtoD").secs,
                dtoh_secs: r.io.get("DtoH").secs,
                um_secs: r.io.get("UM").secs,
            });
        }
    }
    rows
}

// ---------------------------------------------------------------------- Fig 8

/// Fig. 8: achieved storage-path bandwidth. GPU-SSD rides GDS (AIRES's
/// dual-way path); CPU-SSD rides the classic NVMe->host path.
#[derive(Debug, Clone)]
pub struct Fig8Row {
    /// Dataset name.
    pub dataset: String,
    /// Scheduler the row measures.
    pub scheduler: &'static str,
    /// Bytes over the GDS (GPU<->SSD direct) path.
    pub gpu_ssd_bytes: u64,
    /// Achieved GDS bandwidth.
    pub gpu_ssd_gbps: f64,
    /// Bytes over the classic NVMe<->host path.
    pub cpu_ssd_bytes: u64,
    /// Achieved NVMe-host bandwidth.
    pub cpu_ssd_gbps: f64,
}

/// Fig. 8 rows: per (dataset, scheduler) storage-path bandwidth.
pub fn fig8_bandwidth(cm: &CostModel) -> Vec<Fig8Row> {
    let mut rows = Vec::new();
    for d in CATALOG.iter() {
        let w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
        for s in all_schedulers() {
            let r = s.run_epoch(&w, cm);
            if r.oom.is_some() {
                continue;
            }
            rows.push(Fig8Row {
                dataset: d.name.to_string(),
                scheduler: r.scheduler,
                gpu_ssd_bytes: r.io.gpu_ssd_bytes(),
                gpu_ssd_gbps: r.io.bandwidth_gbps(&["GdsRead", "GdsWrite"]),
                cpu_ssd_bytes: r.io.cpu_ssd_bytes(),
                cpu_ssd_gbps: r.io.bandwidth_gbps(&["NvmeToHost", "HostToNvme"]),
            });
        }
    }
    rows
}

// ---------------------------------------------------------------------- Fig 9

/// Fig. 9: per-epoch latency vs GCN feature size (16..256).
#[derive(Debug, Clone)]
pub struct Fig9Row {
    /// Dataset name.
    pub dataset: String,
    /// Feature width this row was evaluated at.
    pub feat_dim: u64,
    /// One [`EpochResult`] per scheduler.
    pub results: Vec<EpochResult>,
}

/// The feature-size sweep of Fig. 9.
pub const FIG9_FEATURES: [u64; 5] = [16, 32, 64, 128, 256];

/// Fig. 9 rows: one dataset swept over [`FIG9_FEATURES`].
pub fn fig9_feature_size(cm: &CostModel, dataset: &str) -> Vec<Fig9Row> {
    let d = crate::graphgen::catalog::by_name(dataset).expect("dataset");
    let w256 = Workload::from_catalog(d, FEAT_DIM, LAYERS);
    let model256 = (w256.a_bytes() + w256.b_bytes() + w256.c_bytes()) as f64;
    FIG9_FEATURES
        .iter()
        .map(|&f| {
            let mut w = Workload::from_catalog(d, f, LAYERS);
            // The catalog req is calibrated at f=256; scale it with the
            // modelled working set so feasibility stays consistent with
            // Fig. 6 at 256 and shrinks for smaller features.
            let model_f = (w.a_bytes() + w.b_bytes() + w.c_bytes()) as f64;
            w.memory_req_bytes =
                Some((w256.req_bytes() as f64 * model_f / model256) as u64);
            Fig9Row {
                dataset: dataset.to_string(),
                feat_dim: f,
                results: all_schedulers().iter().map(|s| s.run_epoch(&w, cm)).collect(),
            }
        })
        .collect()
}

// -------------------------------------------------------------------- Table 3

/// Table III: impact of tightening the GPU memory constraint.
#[derive(Debug, Clone)]
pub struct Table3Row {
    /// Dataset name.
    pub dataset: String,
    /// GPU memory constraint (GB) this row tightened to.
    pub constraint_gb: f64,
    /// (scheduler, per-epoch seconds or None=OOM), paper column order.
    pub cells: Vec<(&'static str, Option<f64>)>,
}

/// The paper's exact (dataset, constraint) grid.
pub const TABLE3_GRID: [(&str, &[f64]); 3] = [
    ("kV1r", &[24.0, 21.0, 19.0]),
    ("kP1a", &[16.0, 14.0, 12.0]),
    ("socLJ1", &[11.0, 10.0, 8.0]),
];

/// Table III rows over [`TABLE3_GRID`].
pub fn table3_memcap(cm: &CostModel) -> Vec<Table3Row> {
    let mut rows = Vec::new();
    for (name, caps) in TABLE3_GRID {
        let d = crate::graphgen::catalog::by_name(name).unwrap();
        for &cap_gb in caps {
            let mut w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
            w.gpu_mem_bytes = (cap_gb * 1e9) as u64;
            let cells = all_schedulers()
                .iter()
                .map(|s| {
                    let r = s.run_epoch(&w, cm);
                    (r.scheduler, r.makespan_s)
                })
                .collect();
            rows.push(Table3Row { dataset: name.to_string(), constraint_gb: cap_gb, cells });
        }
    }
    rows
}

// ------------------------------------------------------------------- helpers

/// Geometric-mean speedup of AIRES over `sched` across completed datasets
/// (the paper's "average total speedup" figure).
pub fn mean_speedup(rows: &[Fig6Row], sched: &str) -> f64 {
    let sp: Vec<f64> = rows.iter().filter_map(|r| r.speedup_over(sched)).collect();
    if sp.is_empty() {
        return f64::NAN;
    }
    (sp.iter().map(|s| s.ln()).sum::<f64>() / sp.len() as f64).exp()
}

/// Ablation: AIRES with individual features disabled (DESIGN.md calls
/// these out; used by the ablation bench).
pub fn ablation_row(d: &DatasetStats, cm: &CostModel) -> Vec<(String, Option<f64>)> {
    let w = Workload::from_catalog(d, FEAT_DIM, LAYERS);
    let mut out = Vec::new();
    let full = crate::sched::Aires.run_epoch(&w, cm);
    out.push(("AIRES (full)".to_string(), full.makespan_s));
    // No dual-way: B rides NVMe->host->PCIe like the baselines. Model via
    // a cost model whose GDS path is as slow as the two-hop path.
    let mut cm_nodual = cm.clone();
    cm_nodual.gds_read_gbps =
        1.0 / (1.0 / cm.nvme_read_gbps + 1.0 / cm.pcie_h2d_gbps);
    let nodual = crate::sched::Aires.run_epoch(&w, &cm_nodual);
    out.push(("AIRES w/o dual-way".to_string(), nodual.makespan_s));
    // No dynamic allocation: pay a malloc per segment at 10x cost (static
    // reallocation churn).
    let mut cm_static = cm.clone();
    cm_static.gpu_malloc_s *= 10.0;
    let nostatic = crate::sched::Aires.run_epoch(&w, &cm_static);
    out.push(("AIRES w/ static alloc churn".to_string(), nostatic.makespan_s));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig3_shape_small_memory_higher_overhead() {
        // Paper's two Fig. 3 observations: non-negligible overheads, and
        // kV2a (smallest memory headroom) ~6x kP1a.
        let cm = CostModel::default();
        let rows = fig3_merging(&cm);
        assert_eq!(rows.len(), 3);
        let kv2a = &rows[0];
        let kp1a = &rows[2];
        assert!(kv2a.overhead_pct > 20.0, "kV2a overhead {:.1}%", kv2a.overhead_pct);
        assert!(
            kv2a.overhead_pct > 3.0 * kp1a.overhead_pct,
            "kV2a {:.1}% should dwarf kP1a {:.1}%",
            kv2a.overhead_pct,
            kp1a.overhead_pct
        );
        for r in &rows {
            assert_eq!(r.robw_overhead_pct, 0.0, "RoBW must remove merging entirely");
        }
    }

    #[test]
    fn fig6_aires_wins_everywhere() {
        let cm = CostModel::default();
        let rows = fig6_speedup(&cm);
        for r in &rows {
            for sched in ["MaxMemory", "UCG", "ETC"] {
                let sp = r.speedup_over(sched).unwrap();
                assert!(sp > 1.0, "{}: AIRES must beat {} (got {:.2}x)", r.dataset, sched, sp);
            }
        }
        // Paper: averages 1.8x / 1.7x / 1.5x; ours must land in the band.
        let mm = mean_speedup(&rows, "MaxMemory");
        let ucg = mean_speedup(&rows, "UCG");
        let etc = mean_speedup(&rows, "ETC");
        assert!((1.5..2.6).contains(&mm), "MaxMemory mean {mm:.2}");
        assert!((1.4..2.2).contains(&ucg), "UCG mean {ucg:.2}");
        assert!((1.2..1.9).contains(&etc), "ETC mean {etc:.2}");
        assert!(mm > ucg && ucg > etc, "ordering must match the paper");
    }

    #[test]
    fn fig7_aires_moves_least_gpu_cpu_data() {
        let cm = CostModel::default();
        let rows = fig7_io_breakdown(&cm);
        for d in CATALOG.iter() {
            let total = |sched: &str| {
                rows.iter()
                    .find(|r| r.dataset == d.name && r.scheduler == sched)
                    .map(|r| r.htod_bytes + r.dtoh_bytes + r.um_bytes)
            };
            let aires = total("AIRES").unwrap();
            for sched in ["MaxMemory", "UCG", "ETC"] {
                if let Some(b) = total(sched) {
                    assert!(
                        aires < b / 2,
                        "{}: AIRES {} should be well below {} {}",
                        d.name,
                        aires,
                        sched,
                        b
                    );
                }
            }
        }
    }

    #[test]
    fn fig8_only_aires_uses_gds() {
        let cm = CostModel::default();
        for r in fig8_bandwidth(&cm) {
            if r.scheduler == "AIRES" {
                assert!(r.gpu_ssd_bytes > 0, "{}: AIRES must use GDS", r.dataset);
                assert!(r.gpu_ssd_gbps > 0.0);
            } else {
                assert_eq!(r.gpu_ssd_bytes, 0, "{} {}", r.dataset, r.scheduler);
            }
        }
    }

    #[test]
    fn fig9_latency_grows_with_feature_size() {
        let cm = CostModel::default();
        let rows = fig9_feature_size(&cm, "kP1a");
        assert_eq!(rows.len(), FIG9_FEATURES.len());
        let mut last = 0.0;
        for r in &rows {
            let aires =
                r.results.iter().find(|x| x.scheduler == "AIRES").unwrap().makespan_s.unwrap();
            assert!(aires > last, "latency must grow with feature size");
            last = aires;
            // AIRES stays fastest at every feature size (paper's claim).
            for x in &r.results {
                if let Some(m) = x.makespan_s {
                    assert!(m >= aires, "{} beat AIRES at f={}", x.scheduler, r.feat_dim);
                }
            }
        }
    }

    #[test]
    fn table3_matches_paper_oom_pattern() {
        let cm = CostModel::default();
        let rows = table3_memcap(&cm);
        assert_eq!(rows.len(), 9);
        for row in &rows {
            let get = |s: &str| row.cells.iter().find(|(n, _)| *n == s).unwrap().1;
            // Paper: level 0 all complete; level 1 only ETC+AIRES; level 2
            // AIRES alone.
            let level = match row.constraint_gb {
                c if c == 24.0 || c == 16.0 || c == 11.0 => 0,
                c if c == 21.0 || c == 14.0 || c == 10.0 => 1,
                _ => 2,
            };
            assert!(get("AIRES").is_some(), "{row:?}");
            assert_eq!(get("ETC").is_some(), level <= 1, "{row:?}");
            assert_eq!(get("MaxMemory").is_some(), level == 0, "{row:?}");
            assert_eq!(get("UCG").is_some(), level == 0, "{row:?}");
        }
    }

    #[test]
    fn ablations_hurt() {
        let cm = CostModel::default();
        let d = crate::graphgen::catalog::by_name("kP1a").unwrap();
        let rows = ablation_row(d, &cm);
        let full = rows[0].1.unwrap();
        for (name, t) in &rows[1..] {
            assert!(t.unwrap() >= full, "{name} should not be faster than full AIRES");
        }
    }
}
