//! Markdown / CSV renderers for the experiment harnesses.

use super::experiments::*;
use crate::util::{human_bytes, human_secs};
use std::fmt::Write as _;

fn opt_secs(v: Option<f64>) -> String {
    match v {
        Some(t) => format!("{t:.2} s"),
        None => "-".to_string(), // the paper's OOM marker
    }
}

/// Fig. 3 as a markdown table.
pub fn fig3_md(rows: &[Fig3Row]) -> String {
    let mut out = String::from(
        "| Dataset | Segments | Merge time | Compute time | Overhead (naive) | Overhead (RoBW) |\n|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {:.1}% | {:.1}% |",
            r.dataset,
            r.n_segments,
            human_secs(r.merge_secs),
            human_secs(r.compute_secs),
            r.overhead_pct,
            r.robw_overhead_pct
        );
    }
    out
}

/// Fig. 6 as a markdown table (latency + AIRES speedups).
pub fn fig6_md(rows: &[Fig6Row]) -> String {
    let mut out = String::from(
        "| Dataset | MaxMemory | UCG | ETC | AIRES | vs MaxMem | vs UCG | vs ETC |\n|---|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {} | {} | {} |",
            r.dataset,
            opt_secs(r.makespan("MaxMemory")),
            opt_secs(r.makespan("UCG")),
            opt_secs(r.makespan("ETC")),
            opt_secs(r.makespan("AIRES")),
            r.speedup_over("MaxMemory").map_or("-".into(), |s| format!("{s:.2}x")),
            r.speedup_over("UCG").map_or("-".into(), |s| format!("{s:.2}x")),
            r.speedup_over("ETC").map_or("-".into(), |s| format!("{s:.2}x")),
        );
    }
    let _ = writeln!(
        out,
        "\nGeo-mean speedups: {:.2}x (MaxMemory), {:.2}x (UCG), {:.2}x (ETC); paper: 1.8x / 1.7x / 1.5x.",
        mean_speedup(rows, "MaxMemory"),
        mean_speedup(rows, "UCG"),
        mean_speedup(rows, "ETC")
    );
    out
}

/// Fig. 7 as a markdown table.
pub fn fig7_md(rows: &[Fig7Row]) -> String {
    let mut out = String::from(
        "| Dataset | Scheduler | HtoD | DtoH | UM | total bytes | total latency |\n|---|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {} | {} |",
            r.dataset,
            r.scheduler,
            human_bytes(r.htod_bytes),
            human_bytes(r.dtoh_bytes),
            human_bytes(r.um_bytes),
            human_bytes(r.htod_bytes + r.dtoh_bytes + r.um_bytes),
            human_secs(r.htod_secs + r.dtoh_secs + r.um_secs),
        );
    }
    out
}

/// Fig. 8 as a markdown table.
pub fn fig8_md(rows: &[Fig8Row]) -> String {
    let mut out = String::from(
        "| Dataset | Scheduler | GPU-SSD bytes | GPU-SSD bw | CPU-SSD bytes | CPU-SSD bw |\n|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {:.1} GB/s | {} | {:.1} GB/s |",
            r.dataset,
            r.scheduler,
            human_bytes(r.gpu_ssd_bytes),
            r.gpu_ssd_gbps,
            human_bytes(r.cpu_ssd_bytes),
            r.cpu_ssd_gbps,
        );
    }
    out
}

/// Fig. 9 as a markdown table.
pub fn fig9_md(rows: &[Fig9Row]) -> String {
    let mut out = String::from(
        "| Dataset | Feature | MaxMemory | UCG | ETC | AIRES |\n|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let get = |s: &str| {
            r.results
                .iter()
                .find(|x| x.scheduler == s)
                .and_then(|x| x.makespan_s)
        };
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {} |",
            r.dataset,
            r.feat_dim,
            opt_secs(get("MaxMemory")),
            opt_secs(get("UCG")),
            opt_secs(get("ETC")),
            opt_secs(get("AIRES")),
        );
    }
    out
}

/// Table III as a markdown table (the paper's exact layout).
pub fn table3_md(rows: &[Table3Row]) -> String {
    let mut out = String::from(
        "| Dataset | Mem. constraint (GB) | MaxMemory | UCG | ETC | AIRES |\n|---|---|---|---|---|---|\n",
    );
    for r in rows {
        let get = |s: &str| r.cells.iter().find(|(n, _)| *n == s).unwrap().1;
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} | {} |",
            r.dataset,
            r.constraint_gb,
            opt_secs(get("MaxMemory")),
            opt_secs(get("UCG")),
            opt_secs(get("ETC")),
            opt_secs(get("AIRES")),
        );
    }
    out
}

/// Table II (the dataset catalog) as markdown.
pub fn table2_md() -> String {
    let mut out = String::from(
        "| Dataset | Vertices (M) | Edges (M) | Mem. Req. (GB) | Constraint (GB) |\n|---|---|---|---|---|\n",
    );
    for d in crate::graphgen::CATALOG.iter() {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {} |",
            d.name, d.vertices_m, d.edges_m, d.memory_req_gb, d.memory_constraint_gb
        );
    }
    out
}

/// Table I (the feature matrix) as markdown.
pub fn table1_md() -> String {
    let mut out = String::from(
        "| | MaxMemory | UCG | ETC | AIRES |\n|---|---|---|---|---|\n",
    );
    let scheds = crate::sched::all_schedulers();
    let mark = |b: bool| if b { "yes" } else { "no" };
    let rows: [(&str, fn(&crate::sched::Features) -> bool); 5] = [
        ("Alignment", |f| f.alignment),
        ("DMA", |f| f.dma),
        ("UM reads", |f| f.um_reads),
        ("Dual-way", |f| f.dual_way),
        ("Co-Design", |f| f.co_design),
    ];
    for (name, get) in rows {
        let cells: Vec<String> =
            scheds.iter().map(|s| mark(get(&s.features())).to_string()).collect();
        let _ = writeln!(out, "| {} | {} |", name, cells.join(" | "));
    }
    out
}

/// Perf-trajectory summary (`bench report`) as a markdown table: one
/// row per `(scenario, metric)` series with min/p50/p99 across stored
/// runs and the newest run's value.
pub fn bench_trajectory_md(stats: &[crate::benchdb::MetricStats], runs: usize) -> String {
    let mut out = String::new();
    let _ = writeln!(out, "{} stored run(s), {} metric series\n", runs, stats.len());
    out.push_str(
        "| Scenario | Metric | Unit | Samples | Min | p50 | p99 | Latest |\n|---|---|---|---|---|---|---|---|\n",
    );
    for s in stats {
        let _ = writeln!(
            out,
            "| {} | {} | {} | {} | {:.4} | {:.4} | {:.4} | {:.4} |",
            s.scenario, s.metric, s.unit, s.samples, s.min, s.p50, s.p99, s.latest
        );
    }
    out
}

/// Cross-commit trend lines (`bench report`) as a markdown table: one
/// row per gated `(scenario, metric)` series showing the last few runs
/// oldest → latest and the latest value's delta vs the previous commit.
/// Empty when the store holds no gated series yet.
pub fn bench_trend_md(trends: &[crate::benchdb::TrendLine]) -> String {
    if trends.is_empty() {
        return String::new();
    }
    // Bound each cell to the newest runs so wide trajectories stay
    // readable; the aggregate table above already covers the full span.
    const TREND_POINTS: usize = 6;
    let mut out = String::from("\nCross-commit trend (gated metrics):\n\n");
    out.push_str(
        "| Scenario | Metric | Trend (oldest → latest) | Latest | Δ vs prev |\n|---|---|---|---|---|\n",
    );
    for t in trends {
        let tail = &t.points[t.points.len().saturating_sub(TREND_POINTS)..];
        let cells: Vec<String> = tail.iter().map(|p| format!("{:.4}", p.value)).collect();
        let prefix = if t.points.len() > tail.len() { "… " } else { "" };
        let latest = tail.last().expect("series has at least one point");
        let delta = match latest.delta_pct {
            Some(d) => format!("{d:+.2}%"),
            None => "-".to_string(), // first run, or a zero previous value
        };
        let _ = writeln!(
            out,
            "| {} | {} | {}{} | {:.4} {} | {} |",
            t.scenario,
            t.metric,
            prefix,
            cells.join(" → "),
            latest.value,
            t.unit,
            delta,
        );
    }
    out
}

/// Gate verdict (`bench gate`) as a markdown table: one row per gated
/// comparison with the baseline median, the newest run's value, and
/// the relative change (positive = slower).
pub fn bench_gate_md(outcome: &crate::benchdb::GateOutcome) -> String {
    let mut out = String::new();
    if let Some((ts, commit)) = &outcome.latest_run {
        let _ = writeln!(
            out,
            "latest run: commit {commit} at ts {ts}, baseline: {} prior run(s)\n",
            outcome.baseline_runs
        );
    }
    out.push_str(
        "| Scenario | Metric | Baseline median | Latest | Change | Verdict |\n|---|---|---|---|---|---|\n",
    );
    for c in &outcome.checks {
        let _ = writeln!(
            out,
            "| {} | {} | {:.4} {} | {:.4} {} | {:+.2}% | {} |",
            c.scenario,
            c.metric,
            c.baseline_median,
            c.unit,
            c.latest,
            c.unit,
            c.regress_pct,
            if c.failed { "FAIL" } else { "ok" },
        );
    }
    if outcome.skipped_zero_baseline > 0 {
        let _ = writeln!(
            out,
            "\n{} gated metric(s) skipped: zero/negative baseline median.",
            outcome.skipped_zero_baseline
        );
    }
    out
}

/// The full evaluation report (all tables + figures), used by
/// `aires report` and the reproduce_paper example.
pub fn full_report(cm: &crate::memsim::CostModel) -> String {
    let fig6 = fig6_speedup(cm);
    let mut out = String::new();
    let _ = writeln!(out, "# AIRES evaluation report (simulated testbed)\n");
    let _ = writeln!(out, "## Table I — feature matrix\n\n{}", table1_md());
    let _ = writeln!(out, "## Table II — datasets\n\n{}", table2_md());
    let _ = writeln!(out, "## Fig. 3 — merging overhead\n\n{}", fig3_md(&fig3_merging(cm)));
    let _ = writeln!(out, "## Fig. 6 — end-to-end per-epoch latency\n\n{}", fig6_md(&fig6));
    let _ = writeln!(out, "## Fig. 7 — GPU-CPU I/O breakdown\n\n{}", fig7_md(&fig7_io_breakdown(cm)));
    let _ = writeln!(out, "## Fig. 8 — storage-path bandwidth\n\n{}", fig8_md(&fig8_bandwidth(cm)));
    let _ = writeln!(
        out,
        "## Fig. 9 — feature-size ablation (kP1a)\n\n{}",
        fig9_md(&fig9_feature_size(cm, "kP1a"))
    );
    let _ = writeln!(out, "## Table III — memory-constraint ablation\n\n{}", table3_md(&table3_memcap(cm)));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memsim::CostModel;

    #[test]
    fn tables_render() {
        let cm = CostModel::default();
        assert!(table1_md().contains("Dual-way"));
        assert!(table2_md().contains("kV1r"));
        let t3 = table3_md(&table3_memcap(&cm));
        assert!(t3.contains("| - |"), "OOM cells must render as '-':\n{t3}");
    }

    #[test]
    fn bench_tables_render() {
        let stats = vec![crate::benchdb::MetricStats {
            scenario: "fresh_depth1".into(),
            metric: "ns_per_segment".into(),
            unit: "ns".into(),
            samples: 3,
            min: 90.0,
            p50: 100.0,
            p99: 110.0,
            latest: 95.0,
        }];
        let table = bench_trajectory_md(&stats, 3);
        assert!(table.contains("| fresh_depth1 | ns_per_segment | ns | 3 |"), "{table}");

        let outcome = crate::benchdb::GateOutcome {
            latest_run: Some((1722873600, "abc123".into())),
            baseline_runs: 2,
            checks: vec![crate::benchdb::GateCheck {
                scenario: "fresh_depth1".into(),
                metric: "ns_per_segment".into(),
                unit: "ns".into(),
                baseline_median: 100.0,
                latest: 150.0,
                regress_pct: 50.0,
                failed: true,
            }],
            skipped_zero_baseline: 1,
        };
        let table = bench_gate_md(&outcome);
        assert!(table.contains("commit abc123"), "{table}");
        assert!(table.contains("| +50.00% | FAIL |"), "{table}");
        assert!(table.contains("1 gated metric(s) skipped"), "{table}");
    }

    #[test]
    fn bench_trend_table_renders_and_truncates() {
        use crate::benchdb::{TrendLine, TrendPoint};
        assert_eq!(bench_trend_md(&[]), "", "no gated series -> no table");
        // Eight runs: the cell shows only the newest six, with an
        // ellipsis marking the truncation, and the latest delta rendered.
        let points: Vec<TrendPoint> = (0..8)
            .map(|i| TrendPoint {
                run: (i as u64, format!("c{i}")),
                value: 100.0 + i as f64,
                delta_pct: (i > 0).then(|| 100.0 / (99.0 + i as f64)),
            })
            .collect();
        let trends = vec![TrendLine {
            scenario: "train_stream".into(),
            metric: "ns_per_step".into(),
            unit: "ns".into(),
            points,
        }];
        let table = bench_trend_md(&trends);
        assert!(table.contains("| train_stream | ns_per_step |"), "{table}");
        assert!(table.contains("… 102.0000 → "), "truncated to the newest runs: {table}");
        assert!(!table.contains("101.0000 →"), "older points dropped from the cell: {table}");
        assert!(table.contains("107.0000 ns"), "{table}");
        assert!(table.contains("+0.94%"), "latest delta vs previous commit: {table}");
        // A single-point series renders with no delta (nothing previous).
        let one = vec![TrendLine {
            scenario: "s".into(),
            metric: "p99_s".into(),
            unit: "s".into(),
            points: vec![TrendPoint { run: (1, "a".into()), value: 0.5, delta_pct: None }],
        }];
        let table = bench_trend_md(&one);
        assert!(table.contains("| 0.5000 s | - |"), "{table}");
    }

    #[test]
    fn full_report_contains_every_artifact() {
        let cm = CostModel::default();
        let rep = full_report(&cm);
        for h in ["Table I", "Table II", "Fig. 3", "Fig. 6", "Fig. 7", "Fig. 8", "Fig. 9", "Table III"] {
            assert!(rep.contains(h), "missing {h}");
        }
    }
}
