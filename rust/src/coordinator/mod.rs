//! Coordinator: experiment orchestration + reporting.
//!
//! [`experiments`] regenerates every table and figure in the paper's
//! evaluation (§V) from the scheduler simulations and the real-compute
//! substrate; [`report`] renders them as markdown/CSV. The CLI (`aires`)
//! and the bench targets are thin wrappers over these functions, so every
//! number in EXPERIMENTS.md has exactly one source.

pub mod experiments;
pub mod report;

pub use experiments::*;
