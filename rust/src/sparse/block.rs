//! Block-sparse (BSR-like) extraction: the bridge between RoBW-aligned CSR
//! segments and the fixed-shape `bsr_spmm` accelerator artifact.
//!
//! A RoBW segment (complete rows only — paper §III-A) is regridded into
//! `bm x bk` tiles; only tiles containing non-zeros are materialized. The
//! artifact has a static tile budget `NB` per row block, so row blocks with
//! more non-zero tiles are split across multiple artifact invocations and
//! accumulated — the Rust-side analogue of looping a CUDA kernel over tiles.

use super::{Csr, IDX_BYTES, VAL_BYTES};

/// One row block: the dense non-zero tiles covering rows
/// `[block_row*bm, (block_row+1)*bm)`.
#[derive(Debug, Clone)]
pub struct BsrRowBlock {
    /// Index of this row block (rows `block_row*bm ..`).
    pub block_row: usize,
    /// Block-column index of each stored tile (sorted ascending).
    pub colidx: Vec<u32>,
    /// Flat row-major `bm*bk` payloads, tile `t` at `t*bm*bk..` (one
    /// allocation per row block — §Perf: per-tile Vecs cost 10x here).
    pub tiles: Vec<f32>,
}

impl BsrRowBlock {
    /// Dense payload of tile `t`.
    #[inline]
    pub fn tile(&self, t: usize, bm: usize, bk: usize) -> &[f32] {
        &self.tiles[t * bm * bk..(t + 1) * bm * bk]
    }
}

/// Block-sparse matrix with uniform `bm x bk` tiles.
#[derive(Debug, Clone)]
pub struct Bsr {
    /// Logical (unpadded) row count of the source matrix.
    pub nrows: usize,
    /// Logical (unpadded) column count of the source matrix.
    pub ncols: usize,
    /// Tile height.
    pub bm: usize,
    /// Tile width.
    pub bk: usize,
    /// ceil(nrows / bm) row blocks, in order.
    pub row_blocks: Vec<BsrRowBlock>,
}

impl Bsr {
    /// Extract tiles from CSR. Rows/cols beyond the matrix edge are
    /// zero-padded inside the boundary tiles (the artifact shapes are
    /// uniform).
    pub fn from_csr(a: &Csr, bm: usize, bk: usize) -> Bsr {
        assert!(bm > 0 && bk > 0);
        let nrb = a.nrows.div_ceil(bm);
        let mut row_blocks = Vec::with_capacity(nrb);
        for rb in 0..nrb {
            let rlo = rb * bm;
            let rhi = (rlo + bm).min(a.nrows);
            // Pass 1: which block columns are touched?
            let mut touched: Vec<u32> = Vec::new();
            for r in rlo..rhi {
                for (c, _) in a.row(r) {
                    let bc = c / bk as u32;
                    if !touched.contains(&bc) {
                        touched.push(bc);
                    }
                }
            }
            touched.sort_unstable();
            // Pass 2: scatter values into one flat, zeroed payload buffer.
            let mut tiles = vec![0f32; touched.len() * bm * bk];
            for r in rlo..rhi {
                for (c, v) in a.row(r) {
                    let bc = c / bk as u32;
                    let t = touched.binary_search(&bc).unwrap();
                    let lr = r - rlo;
                    let lc = c as usize - bc as usize * bk;
                    tiles[t * bm * bk + lr * bk + lc] = v;
                }
            }
            row_blocks.push(BsrRowBlock { block_row: rb, colidx: touched, tiles });
        }
        Bsr { nrows: a.nrows, ncols: a.ncols, bm, bk, row_blocks }
    }

    /// Total stored (non-zero) tiles.
    pub fn ntiles(&self) -> usize {
        self.row_blocks.iter().map(|rb| rb.colidx.len()).sum()
    }

    /// Number of block columns (ceil(ncols / bk)).
    pub fn nblock_cols(&self) -> usize {
        self.ncols.div_ceil(self.bk)
    }

    /// In-memory footprint: dense tile payloads + block column ids.
    pub fn size_bytes(&self) -> u64 {
        self.ntiles() as u64 * (self.bm * self.bk) as u64 * VAL_BYTES
            + self.ntiles() as u64 * IDX_BYTES
    }

    /// Fill ratio of stored tiles (nnz / stored tile capacity) — the
    /// quantity that decides whether a block shape wastes MXU work.
    pub fn tile_fill_ratio(&self, nnz: usize) -> f64 {
        let cap = self.ntiles() * self.bm * self.bk;
        if cap == 0 {
            return 0.0;
        }
        nnz as f64 / cap as f64
    }

    /// Reconstruct the dense matrix (tests only).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.nrows * self.ncols];
        for rb in &self.row_blocks {
            for (t, &bc) in rb.colidx.iter().enumerate() {
                for lr in 0..self.bm {
                    let r = rb.block_row * self.bm + lr;
                    if r >= self.nrows {
                        break;
                    }
                    for lc in 0..self.bk {
                        let c = bc as usize * self.bk + lc;
                        if c >= self.ncols {
                            break;
                        }
                        let v = rb.tile(t, self.bm, self.bk)[lr * self.bk + lc];
                        if v != 0.0 {
                            out[r * self.ncols + c] = v;
                        }
                    }
                }
            }
        }
        out
    }
}

/// A padded batch ready for one `bsr_spmm` artifact call: exactly `r`
/// row-block slots and `nb` tile slots each, zero-padded, with the valid
/// count carried per slot. Produced by [`pack_artifact_batches`].
#[derive(Debug, Clone)]
pub struct SpmmBatch {
    /// Artifact grid rows; each entry is the global block_row this slot
    /// accumulates into (slots may repeat a block_row when it overflows NB).
    pub slot_block_row: Vec<usize>,
    /// s32[r] valid tile counts.
    pub nblk: Vec<i32>,
    /// s32[r * nb] block-column indices (padded with 0).
    pub colidx: Vec<i32>,
    /// f32[r * nb * bm * bk] tile payloads (padded with 0).
    pub blocks: Vec<f32>,
}

/// Pack a BSR matrix into fixed-shape batches for the `bsr_spmm_{r,nb,bm,bk}`
/// artifact. Row blocks with more than `nb` tiles are split across slots;
/// the executor accumulates slot outputs by `slot_block_row`.
pub fn pack_artifact_batches(bsr: &Bsr, r: usize, nb: usize) -> Vec<SpmmBatch> {
    let bm = bsr.bm;
    let bk = bsr.bk;
    // Expand row blocks into (block_row, tile-range) chunks of <= nb tiles.
    let mut chunks: Vec<(usize, usize, usize)> = Vec::new(); // (rb index, lo, hi)
    for (i, rb) in bsr.row_blocks.iter().enumerate() {
        if rb.colidx.is_empty() {
            continue; // all-zero row block: output rows are zero, skip
        }
        let mut lo = 0;
        while lo < rb.colidx.len() {
            let hi = (lo + nb).min(rb.colidx.len());
            chunks.push((i, lo, hi));
            lo = hi;
        }
    }
    let mut batches = Vec::new();
    for group in chunks.chunks(r) {
        let mut batch = SpmmBatch {
            slot_block_row: Vec::with_capacity(r),
            nblk: vec![0i32; r],
            colidx: vec![0i32; r * nb],
            blocks: vec![0f32; r * nb * bm * bk],
        };
        for (slot, &(rbi, lo, hi)) in group.iter().enumerate() {
            let rb = &bsr.row_blocks[rbi];
            batch.slot_block_row.push(rb.block_row);
            batch.nblk[slot] = (hi - lo) as i32;
            // Contiguous source tiles: one memcpy per slot, not per tile.
            for (j, t) in (lo..hi).enumerate() {
                batch.colidx[slot * nb + j] = rb.colidx[t] as i32;
            }
            let dst = slot * nb * bm * bk;
            let src = &rb.tiles[lo * bm * bk..hi * bm * bk];
            batch.blocks[dst..dst + src.len()].copy_from_slice(src);
        }
        // Unused slots keep nblk = 0 and map to no block_row.
        batches.push(batch);
    }
    batches
}

/// Fused extraction + packing: build `SpmmBatch`es straight from CSR
/// without materializing an intermediate [`Bsr`] (§Perf: the two-step path
/// writes every padded tile payload twice; on hypersparse segments the
/// padding is ~1000x the nnz volume, so halving the writes halves the
/// bridge cost). Semantically identical to
/// `pack_artifact_batches(&Bsr::from_csr(a, bm, bk), r, nb)`.
pub fn pack_csr_batches(a: &Csr, bm: usize, bk: usize, r: usize, nb: usize) -> Vec<SpmmBatch> {
    assert!(bm > 0 && bk > 0);
    let nrb = a.nrows.div_ceil(bm);
    // Pass 1: per row block, the sorted touched block-column list.
    let mut touched_all: Vec<Vec<u32>> = Vec::with_capacity(nrb);
    for rbi in 0..nrb {
        let rlo = rbi * bm;
        let rhi = (rlo + bm).min(a.nrows);
        let mut touched: Vec<u32> = Vec::new();
        for row in rlo..rhi {
            for (c, _) in a.row(row) {
                touched.push(c / bk as u32);
            }
        }
        touched.sort_unstable();
        touched.dedup();
        touched_all.push(touched);
    }
    // Assign (row block, tile chunk) -> global slot, allocate batches.
    // chunk_of[rbi] = (first global slot, #chunks).
    let mut chunk_start = Vec::with_capacity(nrb);
    let mut nslots = 0usize;
    for touched in &touched_all {
        chunk_start.push(nslots);
        nslots += touched.len().div_ceil(nb);
    }
    let nbatches = nslots.div_ceil(r).max(1);
    let mut batches: Vec<SpmmBatch> = (0..nbatches)
        .map(|_| SpmmBatch {
            slot_block_row: Vec::with_capacity(r),
            nblk: vec![0i32; r],
            colidx: vec![0i32; r * nb],
            blocks: vec![0f32; r * nb * bm * bk],
        })
        .collect();
    // Fill metadata (slot -> block row, counts, colidx).
    for (rbi, touched) in touched_all.iter().enumerate() {
        let nchunks = touched.len().div_ceil(nb);
        for ch in 0..nchunks {
            let slot = chunk_start[rbi] + ch;
            let (bi, si) = (slot / r, slot % r);
            let lo = ch * nb;
            let hi = (lo + nb).min(touched.len());
            debug_assert_eq!(batches[bi].slot_block_row.len(), si);
            batches[bi].slot_block_row.push(rbi);
            batches[bi].nblk[si] = (hi - lo) as i32;
            for (j, t) in (lo..hi).enumerate() {
                batches[bi].colidx[si * nb + j] = touched[t] as i32;
            }
        }
    }
    // Pass 2: scatter values directly into the (already zeroed) batch
    // payload buffers — each nnz is written exactly once.
    for (rbi, touched) in touched_all.iter().enumerate() {
        let rlo = rbi * bm;
        let rhi = (rlo + bm).min(a.nrows);
        for row in rlo..rhi {
            let lr = row - rlo;
            for (c, v) in a.row(row) {
                let bc = c / bk as u32;
                let t = touched.binary_search(&bc).unwrap();
                let slot = chunk_start[rbi] + t / nb;
                let j = t % nb;
                let (bi, si) = (slot / r, slot % r);
                let lc = c as usize - bc as usize * bk;
                batches[bi].blocks[(si * nb + j) * bm * bk + lr * bk + lc] = v;
            }
        }
    }
    batches
}

/// Parallel fused packer: semantics identical to [`pack_csr_batches`]
/// (differentially enforced by `rust/tests/differential.rs`), with the two
/// heavy phases on the pool:
///  * pass 1 (per-row-block touched-tile scan + sort) runs as row-block
///    chunks via `map_tasks` — each row block's list is independent;
///  * batch allocation + metadata fill runs one task per batch — a batch
///    owns its buffers, so tasks write disjoint memory.
/// The value scatter stays serial: it writes into many batches at once and
/// is one store per nnz, far below the padded-payload zeroing the parallel
/// phases absorb. Output is deterministic for every thread count (no task
/// writes another task's slots; merges are index-ordered).
pub fn pack_csr_batches_par(
    a: &Csr,
    bm: usize,
    bk: usize,
    r: usize,
    nb: usize,
    pool: &crate::runtime::pool::Pool,
) -> Vec<SpmmBatch> {
    assert!(bm > 0 && bk > 0);
    let nrb = a.nrows.div_ceil(bm);

    // Pass 1 (parallel): per row block, the sorted touched block-column list.
    let rb_ranges = crate::runtime::pool::chunk_ranges(nrb, pool.threads().saturating_mul(4).max(1));
    let touched_chunks: Vec<Vec<Vec<u32>>> = pool.map_tasks(rb_ranges.len(), |ci| {
        let range = rb_ranges[ci].clone();
        let mut out = Vec::with_capacity(range.len());
        for rbi in range {
            let rlo = rbi * bm;
            let rhi = (rlo + bm).min(a.nrows);
            let mut touched: Vec<u32> = Vec::new();
            for row in rlo..rhi {
                for (c, _) in a.row(row) {
                    touched.push(c / bk as u32);
                }
            }
            touched.sort_unstable();
            touched.dedup();
            out.push(touched);
        }
        out
    });
    let touched_all: Vec<Vec<u32>> = touched_chunks.into_iter().flatten().collect();
    debug_assert_eq!(touched_all.len(), nrb);

    // Slot assignment (serial prefix sum, cheap).
    let mut chunk_start = Vec::with_capacity(nrb);
    let mut slot_rb: Vec<(usize, usize)> = Vec::new(); // slot -> (row block, chunk)
    let mut nslots = 0usize;
    for (rbi, touched) in touched_all.iter().enumerate() {
        chunk_start.push(nslots);
        let nchunks = touched.len().div_ceil(nb);
        for ch in 0..nchunks {
            slot_rb.push((rbi, ch));
        }
        nslots += nchunks;
    }

    // Batch allocation + metadata (parallel, one task per batch).
    let nbatches = nslots.div_ceil(r).max(1);
    let mut batches: Vec<SpmmBatch> = pool.map_tasks(nbatches, |bi| {
        let mut batch = SpmmBatch {
            slot_block_row: Vec::with_capacity(r),
            nblk: vec![0i32; r],
            colidx: vec![0i32; r * nb],
            blocks: vec![0f32; r * nb * bm * bk],
        };
        let lo_slot = bi * r;
        let hi_slot = (lo_slot + r).min(nslots);
        for slot in lo_slot..hi_slot {
            let (rbi, ch) = slot_rb[slot];
            let touched = &touched_all[rbi];
            let si = slot - lo_slot;
            let lo = ch * nb;
            let hi = (lo + nb).min(touched.len());
            batch.slot_block_row.push(rbi);
            batch.nblk[si] = (hi - lo) as i32;
            for (j, t) in (lo..hi).enumerate() {
                batch.colidx[si * nb + j] = touched[t] as i32;
            }
        }
        batch
    });

    // Pass 2 (serial): scatter each nnz into its unique destination.
    for (rbi, touched) in touched_all.iter().enumerate() {
        let rlo = rbi * bm;
        let rhi = (rlo + bm).min(a.nrows);
        for row in rlo..rhi {
            let lr = row - rlo;
            for (c, v) in a.row(row) {
                let bc = c / bk as u32;
                let t = touched.binary_search(&bc).unwrap();
                let slot = chunk_start[rbi] + t / nb;
                let j = t % nb;
                let (bi, si) = (slot / r, slot % r);
                let lc = c as usize - bc as usize * bk;
                batches[bi].blocks[(si * nb + j) * bm * bk + lr * bk + lc] = v;
            }
        }
    }
    batches
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, rng.normal() as f32);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn bsr_roundtrip_dense() {
        let mut rng = Pcg::seed(31);
        for &(m, n, bm, bk) in &[(16usize, 16usize, 4usize, 4usize), (17, 13, 4, 8), (5, 5, 8, 8)] {
            let a = random_csr(&mut rng, m, n, 0.2);
            let bsr = Bsr::from_csr(&a, bm, bk);
            assert_eq!(bsr.to_dense(), a.to_dense(), "shape ({m},{n}) tiles ({bm},{bk})");
        }
    }

    #[test]
    fn empty_matrix_has_no_tiles() {
        let a = Csr::empty(8, 8);
        let bsr = Bsr::from_csr(&a, 4, 4);
        assert_eq!(bsr.ntiles(), 0);
        assert_eq!(bsr.row_blocks.len(), 2);
    }

    #[test]
    fn tile_count_reflects_structure() {
        // Single diagonal: one tile per row block.
        let mut coo = Coo::new(16, 16);
        for i in 0..16 {
            coo.push(i, i, 1.0);
        }
        let bsr = Bsr::from_csr(&coo.to_csr(), 4, 4);
        assert_eq!(bsr.ntiles(), 4);
        for rb in &bsr.row_blocks {
            assert_eq!(rb.colidx, vec![rb.block_row as u32]);
        }
    }

    #[test]
    fn pack_splits_overflowing_row_blocks() {
        // Dense row => many tiles in one row block.
        let mut coo = Coo::new(4, 64);
        for c in 0..64 {
            coo.push(0, c, 1.0);
        }
        let bsr = Bsr::from_csr(&coo.to_csr(), 4, 4); // 16 tiles in block 0
        let batches = pack_artifact_batches(&bsr, 2, 4); // nb=4 -> 4 chunks, r=2 -> 2 batches
        assert_eq!(batches.len(), 2);
        let total_valid: i32 = batches.iter().flat_map(|b| b.nblk.iter()).sum();
        assert_eq!(total_valid, 16);
        for b in &batches {
            for &br in &b.slot_block_row {
                assert_eq!(br, 0);
            }
        }
    }

    #[test]
    fn pack_then_cpu_execute_matches_spmm() {
        // Emulate the artifact semantics on CPU and compare against spmm.
        use crate::sparse::spmm::{spmm, Dense};
        let mut rng = Pcg::seed(33);
        let a = random_csr(&mut rng, 24, 32, 0.15);
        let h = Dense::from_vec(
            32,
            5,
            (0..32 * 5).map(|_| rng.normal() as f32).collect(),
        );
        let bm = 8;
        let bk = 8;
        let bsr = Bsr::from_csr(&a, bm, bk);
        let batches = pack_artifact_batches(&bsr, 2, 2);
        let mut out = Dense::zeros(24, 5);
        for b in &batches {
            for (slot, &brow) in b.slot_block_row.iter().enumerate() {
                for j in 0..b.nblk[slot] as usize {
                    let bc = b.colidx[slot * 2 + j] as usize;
                    let tile = &b.blocks[(slot * 2 + j) * bm * bk..(slot * 2 + j + 1) * bm * bk];
                    for lr in 0..bm {
                        let r = brow * bm + lr;
                        if r >= 24 {
                            break;
                        }
                        for lc in 0..bk {
                            let k = bc * bk + lc;
                            if k >= 32 {
                                break;
                            }
                            let av = tile[lr * bk + lc];
                            if av == 0.0 {
                                continue;
                            }
                            for f in 0..5 {
                                *out.at_mut(r, f) += av * h.at(k, f);
                            }
                        }
                    }
                }
            }
        }
        let want = spmm(&a, &h);
        assert!(out.max_abs_diff(&want) < 1e-4);
    }

    #[test]
    fn fused_pack_equals_two_step() {
        let mut rng = Pcg::seed(35);
        for &(m, n, bm, bk, r, nb) in
            &[(64usize, 128usize, 8usize, 8usize, 4usize, 3usize), (33, 70, 16, 8, 2, 5), (10, 10, 4, 4, 8, 16)]
        {
            let a = random_csr(&mut rng, m, n, 0.1);
            let two_step = pack_artifact_batches(&Bsr::from_csr(&a, bm, bk), r, nb);
            let fused = pack_csr_batches(&a, bm, bk, r, nb);
            assert_eq!(two_step.len(), fused.len());
            for (x, y) in two_step.iter().zip(fused.iter()) {
                assert_eq!(x.slot_block_row, y.slot_block_row);
                assert_eq!(x.nblk, y.nblk);
                assert_eq!(x.colidx, y.colidx);
                assert_eq!(x.blocks, y.blocks);
            }
        }
    }

    #[test]
    fn parallel_pack_equals_serial_fused() {
        use crate::runtime::pool::Pool;
        let mut rng = Pcg::seed(36);
        for &(m, n, bm, bk, r, nb) in
            &[(64usize, 128usize, 8usize, 8usize, 4usize, 3usize), (33, 70, 16, 8, 2, 5), (3, 90, 4, 4, 2, 2)]
        {
            let a = random_csr(&mut rng, m, n, 0.12);
            let want = pack_csr_batches(&a, bm, bk, r, nb);
            for threads in [1usize, 2, 4, 8] {
                let got = pack_csr_batches_par(&a, bm, bk, r, nb, &Pool::new(threads));
                assert_eq!(want.len(), got.len(), "threads={threads}");
                for (x, y) in want.iter().zip(got.iter()) {
                    assert_eq!(x.slot_block_row, y.slot_block_row, "threads={threads}");
                    assert_eq!(x.nblk, y.nblk, "threads={threads}");
                    assert_eq!(x.colidx, y.colidx, "threads={threads}");
                    assert_eq!(x.blocks, y.blocks, "threads={threads}");
                }
            }
        }
    }

    #[test]
    fn fill_ratio_bounds() {
        let mut rng = Pcg::seed(34);
        let a = random_csr(&mut rng, 32, 32, 0.1);
        let bsr = Bsr::from_csr(&a, 8, 8);
        let fill = bsr.tile_fill_ratio(a.nnz());
        assert!(fill > 0.0 && fill <= 1.0);
    }
}
