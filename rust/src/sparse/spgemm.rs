//! Reference SpGEMM: the CPU correctness oracle for the accelerator path.
//!
//! Three algorithms:
//!  * `spgemm_gustavson` — row-wise Gustavson with a dense accumulator;
//!    the oracle every other SpGEMM implementation in the repo is checked
//!    against.
//!  * `spgemm_gustavson_par` — the row-range parallel variant on
//!    [`crate::runtime::pool::Pool`]: fixed contiguous row chunks computed
//!    independently (each with its own accumulator) and merged in row
//!    order. Per-row arithmetic order is identical to the serial path, so
//!    the output is byte-identical to `spgemm_gustavson` at every thread
//!    count (the `rust/tests/differential.rs` contract).
//!  * `spgemm_csr_csc` — the paper's formulation (CSR A rows matched
//!    against CSC B columns, §III-B "matching process"); also returns the
//!    match count used to validate the Eq. 5 output-memory model.

use crate::runtime::pool::{chunk_ranges, Pool};

use super::{Csc, Csr};

/// Gustavson SpGEMM: C = A·B, both CSR. Dense accumulator per row —
/// O(nnz(A) * avg_row(B)) time, O(ncols(B)) scratch.
///
/// # Examples
///
/// Multiplying by the identity returns the operand unchanged:
///
/// ```
/// use aires::sparse::spgemm::spgemm_gustavson;
/// use aires::sparse::Coo;
///
/// // A = [[1, 2], [0, 1]]
/// let mut a = Coo::new(2, 2);
/// a.push(0, 0, 1.0);
/// a.push(0, 1, 2.0);
/// a.push(1, 1, 1.0);
/// let a = a.to_csr();
///
/// // B = I
/// let mut b = Coo::new(2, 2);
/// b.push(0, 0, 1.0);
/// b.push(1, 1, 1.0);
///
/// let c = spgemm_gustavson(&a, &b.to_csr());
/// assert_eq!(c, a);
/// ```
pub fn spgemm_gustavson(a: &Csr, b: &Csr) -> Csr {
    assert_eq!(a.ncols, b.nrows, "inner dimension mismatch");
    let n = b.ncols;
    let mut acc = vec![0f32; n];
    // Stamp array marks columns touched in the current row in O(1) — a
    // `contains` scan here is quadratic on hub rows (§Perf: 12x on RMAT).
    let mut stamp = vec![u32::MAX; n];
    let mut touched: Vec<u32> = Vec::new();

    let mut rowptr = Vec::with_capacity(a.nrows + 1);
    rowptr.push(0usize);
    let mut colidx: Vec<u32> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();

    for i in 0..a.nrows {
        touched.clear();
        for (k, av) in a.row(i) {
            for (j, bv) in b.row(k as usize) {
                if stamp[j as usize] != i as u32 {
                    stamp[j as usize] = i as u32;
                    touched.push(j);
                }
                acc[j as usize] += av * bv;
            }
        }
        touched.sort_unstable();
        for &j in &touched {
            // Keep explicit zeros out (exact cancellation is rare but real).
            let v = acc[j as usize];
            if v != 0.0 {
                colidx.push(j);
                vals.push(v);
            }
            acc[j as usize] = 0.0;
        }
        rowptr.push(colidx.len());
    }
    Csr { nrows: a.nrows, ncols: n, rowptr, colidx, vals }
}

/// Worker-local Gustavson scratch (O(ncols(B)) — allocated once per pool
/// worker via `map_tasks_init`, reused across every chunk that worker
/// claims). Safe to reuse: `stamp` entries hold previously processed row
/// ids, and row ranges are disjoint, so a stale entry can never equal the
/// current row; `acc` is restored to exact 0.0 after every row.
struct GustScratch {
    acc: Vec<f32>,
    stamp: Vec<u32>,
    touched: Vec<u32>,
}

impl GustScratch {
    fn new(ncols_b: usize) -> GustScratch {
        GustScratch { acc: vec![0f32; ncols_b], stamp: vec![u32::MAX; ncols_b], touched: Vec::new() }
    }
}

/// Gustavson over the row range `[lo, hi)` of A. The inner loops mirror
/// `spgemm_gustavson` exactly (same traversal, same accumulation order,
/// same explicit-zero drop), which is what makes the parallel path
/// bit-compatible with the serial oracle.
fn gustavson_rows(
    a: &Csr,
    b: &Csr,
    lo: usize,
    hi: usize,
    s: &mut GustScratch,
) -> (Vec<usize>, Vec<u32>, Vec<f32>) {
    // Row pointers relative to this range (rowptr[0] == 0).
    let mut rowptr = Vec::with_capacity(hi - lo + 1);
    rowptr.push(0usize);
    let mut colidx: Vec<u32> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();

    for i in lo..hi {
        s.touched.clear();
        for (k, av) in a.row(i) {
            for (j, bv) in b.row(k as usize) {
                if s.stamp[j as usize] != i as u32 {
                    s.stamp[j as usize] = i as u32;
                    s.touched.push(j);
                }
                s.acc[j as usize] += av * bv;
            }
        }
        s.touched.sort_unstable();
        for &j in &s.touched {
            let v = s.acc[j as usize];
            if v != 0.0 {
                colidx.push(j);
                vals.push(v);
            }
            s.acc[j as usize] = 0.0;
        }
        rowptr.push(colidx.len());
    }
    (rowptr, colidx, vals)
}

/// Row-range parallel Gustavson SpGEMM: C = A·B on the thread pool.
///
/// Rows are split into `4 * threads` contiguous chunks (extra chunks let
/// the pool's self-scheduling absorb hub-row skew); each chunk runs
/// [`gustavson_rows`]; the ordered merge concatenates chunk outputs by row.
/// Deterministic: byte-identical to [`spgemm_gustavson`] for every thread
/// count, because each output row is produced by exactly one task with the
/// serial per-row arithmetic order.
pub fn spgemm_gustavson_par(a: &Csr, b: &Csr, pool: &Pool) -> Csr {
    assert_eq!(a.ncols, b.nrows, "inner dimension mismatch");
    let ranges = chunk_ranges(a.nrows, pool.threads().saturating_mul(4).max(1));
    let parts = pool.map_tasks_init(
        ranges.len(),
        || GustScratch::new(b.ncols),
        |scratch, i| {
            let r = &ranges[i];
            gustavson_rows(a, b, r.start, r.end, scratch)
        },
    );

    // Ordered merge (pure concatenation: chunks hold complete rows).
    let nnz: usize = parts.iter().map(|(_, c, _)| c.len()).sum();
    let mut rowptr = Vec::with_capacity(a.nrows + 1);
    rowptr.push(0usize);
    let mut colidx: Vec<u32> = Vec::with_capacity(nnz);
    let mut vals: Vec<f32> = Vec::with_capacity(nnz);
    for (rp, ci, vs) in parts {
        let base = *rowptr.last().unwrap();
        rowptr.extend(rp[1..].iter().map(|p| p + base));
        colidx.extend_from_slice(&ci);
        vals.extend_from_slice(&vs);
    }
    Csr { nrows: a.nrows, ncols: b.ncols, rowptr, colidx, vals }
}

/// Result of the CSR×CSC formulation: the product plus the number of
/// (row, column) pairs whose index sets intersected — the paper's "matches",
/// which determine the dynamic output allocation (Eq. 5).
pub struct CsrCscProduct {
    /// The product C = A·B.
    pub c: Csr,
    /// Count of output non-zeros before cancellation (== nnz(C) in practice).
    pub matches: u64,
    /// Total scalar multiply-adds performed.
    pub flops: u64,
}

/// SpGEMM in the paper's CSR(A) × CSC(B) form: for every row i of A and
/// column j of B, sorted-list intersection of their index sets.
/// Slower than Gustavson (O(rows·cols) pair enumeration) — use on small
/// operands; exists to model/validate the paper's matching semantics.
pub fn spgemm_csr_csc(a: &Csr, b: &Csc) -> CsrCscProduct {
    assert_eq!(a.ncols, b.nrows, "inner dimension mismatch");
    let mut rowptr = vec![0usize; a.nrows + 1];
    let mut colidx: Vec<u32> = Vec::new();
    let mut vals: Vec<f32> = Vec::new();
    let mut matches = 0u64;
    let mut flops = 0u64;

    for i in 0..a.nrows {
        let arow_lo = a.rowptr[i];
        let arow_hi = a.rowptr[i + 1];
        if arow_lo == arow_hi {
            rowptr[i + 1] = colidx.len();
            continue;
        }
        for j in 0..b.ncols {
            // Sorted two-pointer intersection of A row i with B column j.
            let (mut p, mut q) = (arow_lo, b.colptr[j]);
            let (pe, qe) = (arow_hi, b.colptr[j + 1]);
            let mut acc = 0f32;
            let mut hit = false;
            while p < pe && q < qe {
                let ac = a.colidx[p];
                let br = b.rowidx[q];
                if ac == br {
                    acc += a.vals[p] * b.vals[q];
                    flops += 2;
                    hit = true;
                    p += 1;
                    q += 1;
                } else if ac < br {
                    p += 1;
                } else {
                    q += 1;
                }
            }
            if hit {
                matches += 1;
                if acc != 0.0 {
                    colidx.push(j as u32);
                    vals.push(acc);
                }
            }
        }
        rowptr[i + 1] = colidx.len();
    }
    CsrCscProduct { c: Csr { nrows: a.nrows, ncols: b.ncols, rowptr, colidx, vals }, matches, flops }
}

/// Upper bound on nnz(C) by row-wise FLOP counting (Gustavson symbolic
/// phase); the classical estimator the paper's Eq. 5 replaces with a
/// sparsity-based closed form.
pub fn symbolic_nnz_upper_bound(a: &Csr, b: &Csr) -> u64 {
    let mut total = 0u64;
    for i in 0..a.nrows {
        for (k, _) in a.row(i) {
            total += b.row_nnz(k as usize) as u64;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, (rng.range(1, 10)) as f32 * 0.5);
                }
            }
        }
        coo.to_csr()
    }

    fn dense_matmul(a: &[f32], b: &[f32], m: usize, k: usize, n: usize) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for kk in 0..k {
                let av = a[i * k + kk];
                if av == 0.0 {
                    continue;
                }
                for j in 0..n {
                    c[i * n + j] += av * b[kk * n + j];
                }
            }
        }
        c
    }

    #[test]
    fn gustavson_matches_dense() {
        let mut rng = Pcg::seed(5);
        for _ in 0..10 {
            let m = rng.range(1, 20);
            let k = rng.range(1, 20);
            let n = rng.range(1, 20);
            let a = random_csr(&mut rng, m, k, 0.3);
            let b = random_csr(&mut rng, k, n, 0.3);
            let c = spgemm_gustavson(&a, &b);
            c.validate().unwrap();
            let want = dense_matmul(&a.to_dense(), &b.to_dense(), m, k, n);
            let got = c.to_dense();
            for (g, w) in got.iter().zip(want.iter()) {
                assert!((g - w).abs() < 1e-4, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn csr_csc_matches_gustavson() {
        let mut rng = Pcg::seed(6);
        for _ in 0..10 {
            let m = rng.range(1, 16);
            let k = rng.range(1, 16);
            let n = rng.range(1, 16);
            let a = random_csr(&mut rng, m, k, 0.35);
            let b = random_csr(&mut rng, k, n, 0.35);
            let via_csc = spgemm_csr_csc(&a, &b.to_csc());
            let gust = spgemm_gustavson(&a, &b);
            assert_eq!(via_csc.c.to_dense(), gust.to_dense());
            // With positive-ish values cancellation is absent, so matches == nnz.
            assert_eq!(via_csc.matches, gust.nnz() as u64);
        }
    }

    #[test]
    fn empty_operands() {
        let a = Csr::empty(3, 4);
        let b = Csr::empty(4, 2);
        let c = spgemm_gustavson(&a, &b);
        assert_eq!(c.nnz(), 0);
        assert_eq!(c.nrows, 3);
        assert_eq!(c.ncols, 2);
    }

    #[test]
    fn symbolic_bound_is_upper_bound() {
        let mut rng = Pcg::seed(7);
        let a = random_csr(&mut rng, 12, 12, 0.3);
        let b = random_csr(&mut rng, 12, 12, 0.3);
        let c = spgemm_gustavson(&a, &b);
        assert!(symbolic_nnz_upper_bound(&a, &b) >= c.nnz() as u64);
    }

    #[test]
    fn parallel_matches_serial_oracle_exactly() {
        use crate::runtime::pool::Pool;
        let mut rng = Pcg::seed(9);
        for _ in 0..6 {
            let m = rng.range(1, 40);
            let k = rng.range(1, 40);
            let n = rng.range(1, 40);
            let a = random_csr(&mut rng, m, k, 0.25);
            let b = random_csr(&mut rng, k, n, 0.25);
            let want = spgemm_gustavson(&a, &b);
            for threads in [1usize, 2, 4, 8] {
                let got = spgemm_gustavson_par(&a, &b, &Pool::new(threads));
                got.validate().unwrap();
                assert_eq!(got, want, "threads={threads} m={m} k={k} n={n}");
            }
        }
    }

    #[test]
    fn parallel_handles_empty_and_tiny() {
        use crate::runtime::pool::Pool;
        let pool = Pool::new(8);
        let a = Csr::empty(3, 4);
        let b = Csr::empty(4, 2);
        assert_eq!(spgemm_gustavson_par(&a, &b, &pool), spgemm_gustavson(&a, &b));
        // Fewer rows than workers.
        let mut rng = Pcg::seed(10);
        let a = random_csr(&mut rng, 2, 6, 0.5);
        let b = random_csr(&mut rng, 6, 3, 0.5);
        assert_eq!(spgemm_gustavson_par(&a, &b, &pool), spgemm_gustavson(&a, &b));
    }

    #[test]
    fn identity_is_neutral() {
        let mut rng = Pcg::seed(8);
        let a = random_csr(&mut rng, 9, 9, 0.4);
        let mut eye = Coo::new(9, 9);
        for i in 0..9 {
            eye.push(i, i, 1.0);
        }
        let c = spgemm_gustavson(&a, &eye.to_csr());
        assert_eq!(c.to_dense(), a.to_dense());
    }
}
