//! Graph reordering: bandwidth-reducing permutations that localize CSR
//! columns, raising the BSR tile fill ratio the accelerator path depends
//! on (§Perf finding: scattered columns make padded MXU tiles ~10⁻³ full).
//!
//! Implements reverse Cuthill-McKee (RCM) over the symmetric adjacency and
//! the permutation plumbing to apply it to matrices and feature rows.
//! This is the "future work" lever DESIGN.md calls out for the hypersparse
//! padding wall; the `micro_hotpath` bench quantifies the fill gain.

use super::{Coo, Csr};

/// A vertex permutation: `perm[new] = old` and `inv[old] = new`.
#[derive(Debug, Clone)]
pub struct Permutation {
    /// `perm[new] = old`.
    pub perm: Vec<u32>,
    /// `inv[old] = new`.
    pub inv: Vec<u32>,
}

impl Permutation {
    /// The identity permutation on `0..n`.
    pub fn identity(n: usize) -> Permutation {
        Permutation { perm: (0..n as u32).collect(), inv: (0..n as u32).collect() }
    }

    /// Number of vertices permuted.
    pub fn len(&self) -> usize {
        self.perm.len()
    }

    /// True for the empty (0-vertex) permutation.
    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }

    /// Validate that this is a bijection on 0..n.
    pub fn validate(&self) -> Result<(), String> {
        let n = self.perm.len();
        if self.inv.len() != n {
            return Err("perm/inv length mismatch".into());
        }
        for (new, &old) in self.perm.iter().enumerate() {
            if old as usize >= n || self.inv[old as usize] as usize != new {
                return Err(format!("not a bijection at new={new}"));
            }
        }
        Ok(())
    }
}

/// Reverse Cuthill-McKee ordering of a symmetric CSR adjacency.
/// Disconnected components are processed from successive minimum-degree
/// seeds; the final order is reversed (the "R" in RCM).
pub fn rcm(a: &Csr) -> Permutation {
    assert_eq!(a.nrows, a.ncols, "RCM needs a square adjacency");
    let n = a.nrows;
    let mut visited = vec![false; n];
    let mut order: Vec<u32> = Vec::with_capacity(n);
    // Vertices sorted by degree: seed choice + neighbour ordering.
    let degree = |v: usize| a.row_nnz(v);
    let mut by_degree: Vec<u32> = (0..n as u32).collect();
    by_degree.sort_by_key(|&v| degree(v as usize));

    let mut queue = std::collections::VecDeque::new();
    for &seed in &by_degree {
        if visited[seed as usize] {
            continue;
        }
        visited[seed as usize] = true;
        queue.push_back(seed);
        while let Some(v) = queue.pop_front() {
            order.push(v);
            // Neighbours in increasing degree order.
            let mut nbrs: Vec<u32> =
                a.row(v as usize).map(|(c, _)| c).filter(|&c| !visited[c as usize]).collect();
            nbrs.sort_by_key(|&c| degree(c as usize));
            for c in nbrs {
                if !visited[c as usize] {
                    visited[c as usize] = true;
                    queue.push_back(c);
                }
            }
        }
    }
    order.reverse();
    let mut inv = vec![0u32; n];
    for (new, &old) in order.iter().enumerate() {
        inv[old as usize] = new as u32;
    }
    Permutation { perm: order, inv }
}

/// Apply a symmetric permutation: B[inv[i], inv[j]] = A[i, j].
pub fn permute_symmetric(a: &Csr, p: &Permutation) -> Csr {
    assert_eq!(a.nrows, p.len());
    assert_eq!(a.ncols, p.len());
    let mut coo = Coo::new(a.nrows, a.ncols);
    for i in 0..a.nrows {
        let ni = p.inv[i];
        for (j, v) in a.row(i) {
            coo.push(ni, p.inv[j as usize], v);
        }
    }
    coo.to_csr()
}

/// Permute dense feature rows to match a permuted adjacency.
pub fn permute_rows(x: &super::spmm::Dense, p: &Permutation) -> super::spmm::Dense {
    assert_eq!(x.nrows, p.len());
    let mut out = super::spmm::Dense::zeros(x.nrows, x.ncols);
    for old in 0..x.nrows {
        let new = p.inv[old] as usize;
        out.data[new * x.ncols..(new + 1) * x.ncols].copy_from_slice(x.row(old));
    }
    out
}

/// Matrix bandwidth: max |i - j| over stored entries (what RCM minimizes).
pub fn bandwidth(a: &Csr) -> usize {
    let mut bw = 0usize;
    for i in 0..a.nrows {
        for (j, _) in a.row(i) {
            bw = bw.max((j as i64 - i as i64).unsigned_abs() as usize);
        }
    }
    bw
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Bsr;
    use crate::util::rng::Pcg;

    #[test]
    fn rcm_is_a_permutation() {
        let mut rng = Pcg::seed(41);
        let a = crate::graphgen::kmer::generate(&mut rng, 500, 3.2);
        let p = rcm(&a);
        p.validate().unwrap();
    }

    #[test]
    fn permute_preserves_structure() {
        let mut rng = Pcg::seed(42);
        let a = crate::graphgen::kmer::generate(&mut rng, 300, 3.0);
        let p = rcm(&a);
        let b = permute_symmetric(&a, &p);
        assert_eq!(b.nnz(), a.nnz());
        // Degree multiset is invariant under vertex relabeling.
        let mut da: Vec<usize> = (0..a.nrows).map(|i| a.row_nnz(i)).collect();
        let mut db: Vec<usize> = (0..b.nrows).map(|i| b.row_nnz(i)).collect();
        da.sort_unstable();
        db.sort_unstable();
        assert_eq!(da, db);
    }

    #[test]
    fn rcm_reduces_bandwidth_on_shuffled_kmer() {
        // The shuffled kmer generator scatters columns; RCM must pull the
        // chain structure back toward the diagonal.
        let mut rng = Pcg::seed(43);
        let a = crate::graphgen::kmer::generate(&mut rng, 2000, 3.2);
        let before = bandwidth(&a);
        let after = bandwidth(&permute_symmetric(&a, &rcm(&a)));
        assert!(
            after < before / 2,
            "RCM should at least halve bandwidth: {before} -> {after}"
        );
    }

    #[test]
    fn rcm_improves_tile_fill() {
        // The §Perf motivation: more nnz per touched tile after reordering.
        let mut rng = Pcg::seed(44);
        let a = crate::graphgen::kmer::generate(&mut rng, 2000, 3.2);
        let fill_before = Bsr::from_csr(&a, 32, 32).tile_fill_ratio(a.nnz());
        let b = permute_symmetric(&a, &rcm(&a));
        let fill_after = Bsr::from_csr(&b, 32, 32).tile_fill_ratio(b.nnz());
        assert!(
            fill_after > 1.5 * fill_before,
            "fill {fill_before:.4} -> {fill_after:.4}"
        );
    }

    #[test]
    fn spmm_commutes_with_permutation() {
        // (P A Pᵀ)(P x) == P (A x): reordering must not change results.
        use crate::sparse::spmm::{spmm, Dense};
        let mut rng = Pcg::seed(45);
        let a = crate::graphgen::kmer::generate(&mut rng, 200, 3.0);
        let x = Dense::from_vec(200, 5, (0..1000).map(|_| rng.normal() as f32).collect());
        let p = rcm(&a);
        let lhs = spmm(&permute_symmetric(&a, &p), &permute_rows(&x, &p));
        let rhs = permute_rows(&spmm(&a, &x), &p);
        assert!(lhs.max_abs_diff(&rhs) < 1e-4);
    }

    #[test]
    fn handles_disconnected_graphs() {
        let mut coo = crate::sparse::Coo::new(6, 6);
        coo.push(0, 1, 1.0);
        coo.push(1, 0, 1.0);
        coo.push(3, 4, 1.0);
        coo.push(4, 3, 1.0);
        let a = coo.to_csr();
        let p = rcm(&a);
        p.validate().unwrap();
        assert_eq!(p.len(), 6);
    }
}
