//! Compressed sparse column (CSC) matrix (paper Fig. 2, matrix B's format).

use super::{Csr, IDX_BYTES, PTR_BYTES, VAL_BYTES};

/// CSC matrix: `colptr[j]..colptr[j+1]` indexes the non-zeros of column `j`.
#[derive(Debug, Clone, PartialEq)]
pub struct Csc {
    /// Row count.
    pub nrows: usize,
    /// Column count.
    pub ncols: usize,
    /// len ncols + 1, monotone, last entry == nnz.
    pub colptr: Vec<usize>,
    /// len nnz; row index per non-zero, sorted within each column.
    pub rowidx: Vec<u32>,
    /// len nnz.
    pub vals: Vec<f32>,
}

impl Csc {
    /// Empty matrix with the given shape.
    pub fn empty(nrows: usize, ncols: usize) -> Self {
        Csc { nrows, ncols, colptr: vec![0; ncols + 1], rowidx: Vec::new(), vals: Vec::new() }
    }

    /// Stored non-zero count.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.rowidx.len()
    }

    /// (row, value) iterator over column `j`.
    pub fn col(&self, j: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo = self.colptr[j];
        let hi = self.colptr[j + 1];
        self.rowidx[lo..hi].iter().copied().zip(self.vals[lo..hi].iter().copied())
    }

    /// Sparsity percentage (paper's s_B).
    pub fn sparsity_pct(&self) -> f64 {
        let total = self.nrows as f64 * self.ncols as f64;
        if total == 0.0 {
            return 100.0;
        }
        100.0 * (1.0 - self.nnz() as f64 / total)
    }

    /// Paper Eq. (6): M_B = value bytes + column-offset bytes + row-id bytes.
    pub fn size_bytes(&self) -> u64 {
        self.nnz() as u64 * (VAL_BYTES + IDX_BYTES) + (self.ncols as u64 + 1) * PTR_BYTES
    }

    /// Convert to CSR (counting sort by row).
    pub fn to_csr(&self) -> Csr {
        let mut rowptr = vec![0usize; self.nrows + 1];
        for &r in &self.rowidx {
            rowptr[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            rowptr[i + 1] += rowptr[i];
        }
        let mut colidx = vec![0u32; self.nnz()];
        let mut vals = vec![0f32; self.nnz()];
        let mut next = rowptr.clone();
        for j in 0..self.ncols {
            for (r, v) in self.col(j) {
                let dst = next[r as usize];
                colidx[dst] = j as u32;
                vals[dst] = v;
                next[r as usize] += 1;
            }
        }
        Csr { nrows: self.nrows, ncols: self.ncols, rowptr, colidx, vals }
    }

    /// Dense row-major materialization (tests only).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.nrows * self.ncols];
        for j in 0..self.ncols {
            for (r, v) in self.col(j) {
                out[r as usize * self.ncols + j] = v;
            }
        }
        out
    }

    /// Structural invariant check (mirror of `Csr::validate`).
    pub fn validate(&self) -> Result<(), String> {
        if self.colptr.len() != self.ncols + 1 || self.colptr[0] != 0 {
            return Err("bad colptr".into());
        }
        if *self.colptr.last().unwrap() != self.rowidx.len()
            || self.rowidx.len() != self.vals.len()
        {
            return Err("nnz mismatch".into());
        }
        for w in self.colptr.windows(2) {
            if w[1] < w[0] {
                return Err("colptr not monotone".into());
            }
        }
        for j in 0..self.ncols {
            let col = &self.rowidx[self.colptr[j]..self.colptr[j + 1]];
            for w in col.windows(2) {
                if w[1] <= w[0] {
                    return Err(format!("col {j} rows not strictly sorted"));
                }
            }
            if let Some(&r) = col.last() {
                if r as usize >= self.nrows {
                    return Err(format!("col {j} row {r} out of bounds"));
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_matches_csr_dense() {
        // 2x3: [[1,0,2],[0,3,0]]
        let csr = Csr::new(2, 3, vec![0, 2, 3], vec![0, 2, 1], vec![1.0, 2.0, 3.0]).unwrap();
        let csc = csr.to_csc();
        csc.validate().unwrap();
        assert_eq!(csr.to_dense(), csc.to_dense());
        assert_eq!(csc.nnz(), 3);
    }

    #[test]
    fn eq6_size_bytes() {
        let csc = Csc {
            nrows: 4,
            ncols: 3,
            colptr: vec![0, 1, 1, 2],
            rowidx: vec![0, 3],
            vals: vec![1.0, 2.0],
        };
        assert_eq!(csc.size_bytes(), 2 * 8 + 4 * 8);
    }

    #[test]
    fn col_iterator() {
        let csr = Csr::new(2, 2, vec![0, 2, 3], vec![0, 1, 1], vec![1.0, 2.0, 3.0]).unwrap();
        let csc = csr.to_csc();
        let col1: Vec<(u32, f32)> = csc.col(1).collect();
        assert_eq!(col1, vec![(0, 2.0), (1, 3.0)]);
    }
}
