//! Coordinate (COO) format: the construction/interchange format the graph
//! generators emit before conversion to CSR/CSC.

use super::Csr;

/// COO triplet list. Duplicates are summed on conversion (graph generators
/// may emit the same edge twice).
#[derive(Debug, Clone, Default)]
pub struct Coo {
    /// Row count.
    pub nrows: usize,
    /// Column count.
    pub ncols: usize,
    /// `(row, col, value)` triplets in insertion order.
    pub entries: Vec<(u32, u32, f32)>,
}

impl Coo {
    /// Empty triplet list with the given shape.
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Coo { nrows, ncols, entries: Vec::new() }
    }

    /// Append one `(r, c, v)` triplet.
    #[inline]
    pub fn push(&mut self, r: u32, c: u32, v: f32) {
        debug_assert!((r as usize) < self.nrows && (c as usize) < self.ncols);
        self.entries.push((r, c, v));
    }

    /// Stored triplet count (duplicates not yet merged).
    pub fn nnz(&self) -> usize {
        self.entries.len()
    }

    /// Convert to CSR, sorting and summing duplicate coordinates.
    pub fn to_csr(&self) -> Csr {
        let mut entries = self.entries.clone();
        entries.sort_unstable_by_key(|&(r, c, _)| ((r as u64) << 32) | c as u64);
        let mut rowptr = vec![0usize; self.nrows + 1];
        let mut colidx: Vec<u32> = Vec::with_capacity(entries.len());
        let mut vals: Vec<f32> = Vec::with_capacity(entries.len());
        let mut last: Option<(u32, u32)> = None;
        for (r, c, v) in entries {
            if last == Some((r, c)) {
                *vals.last_mut().unwrap() += v;
            } else {
                colidx.push(c);
                vals.push(v);
                rowptr[r as usize + 1] += 1;
                last = Some((r, c));
            }
        }
        for i in 0..self.nrows {
            rowptr[i + 1] += rowptr[i];
        }
        Csr { nrows: self.nrows, ncols: self.ncols, rowptr, colidx, vals }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn to_csr_sorts_and_dedups() {
        let mut coo = Coo::new(3, 3);
        coo.push(2, 1, 1.0);
        coo.push(0, 0, 2.0);
        coo.push(2, 1, 3.0); // duplicate -> summed
        coo.push(0, 2, 4.0);
        let csr = coo.to_csr();
        csr.validate().unwrap();
        assert_eq!(csr.nnz(), 3);
        let row0: Vec<(u32, f32)> = csr.row(0).collect();
        assert_eq!(row0, vec![(0, 2.0), (2, 4.0)]);
        let row2: Vec<(u32, f32)> = csr.row(2).collect();
        assert_eq!(row2, vec![(1, 4.0)]);
    }

    #[test]
    fn empty_rows_ok() {
        let mut coo = Coo::new(4, 4);
        coo.push(3, 0, 1.0);
        let csr = coo.to_csr();
        csr.validate().unwrap();
        assert_eq!(csr.row_nnz(0), 0);
        assert_eq!(csr.row_nnz(3), 1);
    }

    #[test]
    fn prop_coo_csr_csc_roundtrips_validate() {
        // Every hop of Coo -> Csr -> Csc -> Csr -> Coo preserves the matrix
        // and keeps the structural invariants, across random and
        // pathological shapes (empty rows, hub row, 1xN, Nx1).
        use crate::testing::{check, gen};
        check("coo<->csr<->csc roundtrip", 30, |rng| {
            let a = if rng.chance(0.5) {
                gen::csr(rng, 24, 0.35)
            } else {
                gen::pathological(rng, 24)
            };
            a.validate()?;
            let via_coo = a.to_coo().to_csr();
            if via_coo != a {
                return Err("csr -> coo -> csr not identity".into());
            }
            let csc = a.to_csc();
            csc.validate()?;
            let back = csc.to_csr();
            back.validate()?;
            if back != a {
                return Err("csr -> csc -> csr not identity".into());
            }
            if back.to_coo().to_csr() != a {
                return Err("full loop not identity".into());
            }
            Ok(())
        });
    }
}
