//! On-disk segment I/O: the versioned, checksummed binary format RoBW/CSR
//! segments — and, since the cross-layer pipeline, dense feature panels —
//! are spilled to and staged back from (paper §III-B's tiered
//! GPU ↔ NVMe ↔ host-RAM system, made concrete).
//!
//! Layout (fixed little-endian, so files are byte-stable across runs and
//! platforms — the differential suite compares encodings with `==`):
//!
//! ```text
//! offset  size  field
//! 0       8     magic            b"AIRESSEG"
//! 8       4     format version   u32 (currently 1)
//! 12      4     record kind      u32 (0 = CSR segment, 1 = dense panel,
//!                                     2 = checkpoint blob,
//!                                     3 = packed CSR segment)
//! 16      8     nrows            u64
//! 24      8     ncols            u64
//! 32      8     nnz              u64 (must be 0 for dense panels)
//! 40      8     payload length   u64 (bytes after the 64-byte header)
//! 48      8     payload checksum FNV-1a 64 over the payload bytes
//! 56      8     header checksum  FNV-1a 64 over bytes 0..56
//! 64      ...   payload, by record kind:
//!               CSR segment: rowptr (nrows+1 × u64) ++ colidx (nnz × u32)
//!                            ++ vals (nnz × f32 bit patterns)
//!               dense panel: nrows × ncols row-major f32 bit patterns
//!               checkpoint blob: opaque caller-defined bytes (all three
//!                                count fields zero)
//!               packed CSR segment: rowptr (nrows+1 × u64)
//!                            ++ [bit width w: u8][7 zero pad bytes]
//!                            ++ ceil(nnz·w / 64) × u64 packed colidx words
//!                               (per-row zigzag deltas, LSB-first)
//!                            ++ vals (nnz × f32 bit patterns)
//! ```
//!
//! The record-kind field occupies what version 1 originally reserved as a
//! must-be-zero word, so every pre-existing CSR segment file is already a
//! valid `KIND_CSR` record — the golden vectors below pin both layouts.
//!
//! Decoding is strict: every structural defect maps to a typed
//! [`SegioError`] (wrong magic, unsupported version, wrong record kind,
//! truncation, checksum mismatch, CSR-invariant violation), so the
//! streaming layer can abort cleanly instead of computing on garbage.
//! Checks run in layout order — magic, then version, then record kind,
//! then header checksum, then lengths, then payload checksum, then
//! structural validation — so the reported error names the outermost
//! defect. Feeding a panel file to the CSR decoder (or vice versa) is a
//! [`SegioError::WrongKind`], never a silent misread: the two payloads are
//! length-checked against different formulas and share no interpretation.

use super::spmm::Dense;
use super::Csr;
use std::io::{Read, Write};
use std::path::Path;

/// File magic: the first 8 bytes of every segment file.
pub const MAGIC: [u8; 8] = *b"AIRESSEG";
/// Current (and only) format version.
pub const FORMAT_VERSION: u32 = 1;
/// Record kind of a sparse CSR segment (the original, and default, kind).
pub const KIND_CSR: u32 = 0;
/// Record kind of a dense feature panel (row-major f32 payload).
pub const KIND_PANEL: u32 = 1;
/// Record kind of an opaque checkpoint blob (caller-defined payload under
/// the shared header/checksum discipline; all three count fields are 0).
pub const KIND_CHECK: u32 = 2;
/// Record kind of a packed CSR segment: same rowptr/vals sections as
/// [`KIND_CSR`], but the colidx section is per-row zigzag deltas bitpacked
/// at one per-segment width. Decodes to the identical matrix.
pub const KIND_CSR_PACKED: u32 = 3;
/// Fixed header size in bytes; the payload starts here.
pub const HEADER_BYTES: usize = 64;
/// Upper bound on the packed colidx bit width: a zigzagged difference of
/// two `u32` columns spans at most 33 bits, so any larger stored width is
/// a crafted header, not an encoder output.
pub const PACKED_WIDTH_MAX: u32 = 33;

/// Typed decode/read failure. Every variant names the defect precisely so
/// fault-injection tests can assert on *which* check fired.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SegioError {
    /// The buffer/file ends before the advertised structure does.
    Truncated {
        /// Bytes the structure requires.
        need: u64,
        /// Bytes actually present.
        got: u64,
    },
    /// The first 8 bytes are not [`MAGIC`].
    BadMagic,
    /// Version field differs from [`FORMAT_VERSION`].
    WrongVersion {
        /// Version the file claims.
        found: u32,
        /// Version this build understands.
        expected: u32,
    },
    /// Record-kind field does not match the decoder (a dense panel fed to
    /// the CSR decoder, or vice versa) — valid file, wrong reader.
    WrongKind {
        /// Kind the file claims ([`KIND_CSR`] / [`KIND_PANEL`]).
        found: u32,
        /// Kind this decoder reads.
        expected: u32,
    },
    /// Header bytes fail their checksum (corrupt metadata).
    HeaderChecksum {
        /// Checksum stored in the file.
        stored: u64,
        /// Checksum recomputed from the bytes.
        computed: u64,
    },
    /// Payload bytes fail their checksum (corrupt section data).
    PayloadChecksum {
        /// Checksum stored in the file.
        stored: u64,
        /// Checksum recomputed from the bytes.
        computed: u64,
    },
    /// Sections decode but violate a CSR invariant (e.g. non-monotone
    /// rowptr) — structurally valid bytes, semantically invalid matrix.
    InvalidCsr(String),
    /// Panel header fields are inconsistent (payload length not
    /// `nrows × ncols × 4`, dimension overflow, non-zero nnz slot).
    InvalidPanel(String),
    /// Checkpoint-blob header fields are inconsistent (non-zero count
    /// fields, payload length beyond the address space).
    InvalidBlob(String),
    /// Underlying filesystem error (with path context).
    Io(String),
}

impl std::fmt::Display for SegioError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SegioError::Truncated { need, got } => {
                write!(f, "segment truncated: need {need} bytes, got {got}")
            }
            SegioError::BadMagic => write!(f, "not a segment file (bad magic)"),
            SegioError::WrongVersion { found, expected } => {
                write!(f, "unsupported segment format version {found} (expected {expected})")
            }
            SegioError::WrongKind { found, expected } => {
                let name = |k: u32| match k {
                    KIND_CSR => "CSR segment",
                    KIND_PANEL => "dense panel",
                    KIND_CHECK => "checkpoint blob",
                    KIND_CSR_PACKED => "packed CSR segment",
                    _ => "unknown",
                };
                write!(
                    f,
                    "wrong record kind {found} ({}): this decoder reads kind {expected} ({})",
                    name(*found),
                    name(*expected)
                )
            }
            SegioError::HeaderChecksum { stored, computed } => write!(
                f,
                "segment header checksum mismatch: \
                 stored {stored:#018x}, computed {computed:#018x}"
            ),
            SegioError::PayloadChecksum { stored, computed } => write!(
                f,
                "segment payload checksum mismatch: \
                 stored {stored:#018x}, computed {computed:#018x}"
            ),
            SegioError::InvalidCsr(msg) => write!(f, "decoded segment is not a valid CSR: {msg}"),
            SegioError::InvalidPanel(msg) => {
                write!(f, "decoded record is not a valid dense panel: {msg}")
            }
            SegioError::InvalidBlob(msg) => {
                write!(f, "decoded record is not a valid checkpoint blob: {msg}")
            }
            SegioError::Io(msg) => write!(f, "segment I/O: {msg}"),
        }
    }
}

impl std::error::Error for SegioError {}

/// Incremental FNV-1a 64 hasher — the same function as [`fnv1a64`], fed
/// in pieces (used by `runtime::segstore` to fingerprint a matrix + plan
/// without materializing one contiguous buffer).
#[derive(Debug, Clone)]
pub struct Fnv64(u64);

impl Fnv64 {
    /// Hasher at the FNV-1a offset basis.
    pub fn new() -> Fnv64 {
        Fnv64(0xcbf2_9ce4_8422_2325)
    }

    /// Absorb `bytes`.
    pub fn update(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x100_0000_01b3);
        }
    }

    /// Current hash value.
    pub fn finish(&self) -> u64 {
        self.0
    }
}

impl Default for Fnv64 {
    fn default() -> Fnv64 {
        Fnv64::new()
    }
}

/// FNV-1a 64-bit hash — the format's checksum. Chosen over CRC32 for the
/// 64-bit state (fewer silent collisions on multi-MiB payloads) while
/// staying dependency-free and byte-order independent.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv64::new();
    h.update(bytes);
    h.finish()
}

/// Process-wide count of segment/panel payload *materializations* (copy
/// decodes of the O(nnz) sections into owned vectors). The zero-copy mmap
/// path never increments it, which is exactly what the warm-path gate in
/// `rust/tests/alloc_free.rs` asserts: a steady-state mapped read serves
/// colidx/vals straight from the page cache.
static PAYLOAD_COPIES: std::sync::atomic::AtomicU64 = std::sync::atomic::AtomicU64::new(0);

/// Current value of the payload-copy counter (monotone; compare deltas).
pub fn payload_copy_count() -> u64 {
    PAYLOAD_COPIES.load(std::sync::atomic::Ordering::Relaxed)
}

/// Segment encoding policy, selected per store by `--seg-encoding`.
///
/// `Raw` writes [`KIND_CSR`] records (the seed format), `Packed` writes
/// [`KIND_CSR_PACKED`], and `Auto` picks per segment: packed iff its
/// predicted file is strictly smaller than the raw file. Every choice
/// decodes to the identical matrix, so the differential suite sweeps this
/// axis against the raw serial oracle with `==`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum SegEncoding {
    /// Plain `u32` colidx section ([`KIND_CSR`]) — the seed default.
    #[default]
    Raw,
    /// Delta + bitpacked colidx section ([`KIND_CSR_PACKED`]).
    Packed,
    /// Per-segment choice by predicted size (smaller file wins; raw on ties).
    Auto,
}

impl SegEncoding {
    /// The encoding that reproduces an existing record's kind byte-for-byte
    /// — how the self-healing rebuild keeps a quarantined segment's
    /// encoding stable (raw stays raw, packed stays packed). `None` for
    /// non-CSR kinds.
    pub fn for_kind(kind: u32) -> Option<SegEncoding> {
        match kind {
            KIND_CSR => Some(SegEncoding::Raw),
            KIND_CSR_PACKED => Some(SegEncoding::Packed),
            _ => None,
        }
    }
}

impl std::str::FromStr for SegEncoding {
    type Err = String;

    fn from_str(s: &str) -> Result<SegEncoding, String> {
        match s {
            "raw" => Ok(SegEncoding::Raw),
            "packed" => Ok(SegEncoding::Packed),
            "auto" => Ok(SegEncoding::Auto),
            other => Err(format!("unknown segment encoding '{other}' (expected raw, packed, or auto)")),
        }
    }
}

impl std::fmt::Display for SegEncoding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            SegEncoding::Raw => "raw",
            SegEncoding::Packed => "packed",
            SegEncoding::Auto => "auto",
        })
    }
}

/// Exact encoded size of a segment with `nrows` rows and `nnz` stored
/// entries — header + rowptr/colidx/val sections. Lets callers (the
/// bench fixture reuse check, the store's spill accounting) predict file
/// sizes without encoding.
pub fn encoded_len(nrows: usize, nnz: usize) -> u64 {
    HEADER_BYTES as u64 + (nrows as u64 + 1) * 8 + nnz as u64 * 4 + nnz as u64 * 4
}

/// Exact encoded size of a dense panel with `nrows × ncols` elements —
/// header + row-major f32 payload (the panel-tier analog of
/// [`encoded_len`]).
pub fn encoded_panel_len(nrows: usize, ncols: usize) -> u64 {
    HEADER_BYTES as u64 + nrows as u64 * ncols as u64 * 4
}

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(buf: &mut Vec<u8>, v: u64) {
    buf.extend_from_slice(&v.to_le_bytes());
}

fn get_u32(buf: &[u8], off: usize) -> u32 {
    u32::from_le_bytes(buf[off..off + 4].try_into().expect("4-byte slice"))
}

fn get_u64(buf: &[u8], off: usize) -> u64 {
    u64::from_le_bytes(buf[off..off + 8].try_into().expect("8-byte slice"))
}

/// Encode a CSR segment into the on-disk byte format. Deterministic: the
/// same matrix always produces the same bytes (enforced by the golden
/// vector below and `rust/tests/segio_roundtrip.rs`).
pub fn encode_segment(m: &Csr) -> Vec<u8> {
    let nnz = m.nnz();
    let payload_len = (m.nrows + 1) * 8 + nnz * 8;
    let mut payload = Vec::with_capacity(payload_len);
    for &p in &m.rowptr {
        put_u64(&mut payload, p as u64);
    }
    for &c in &m.colidx {
        put_u32(&mut payload, c);
    }
    for &v in &m.vals {
        put_u32(&mut payload, v.to_bits());
    }
    debug_assert_eq!(payload.len(), payload_len);

    seal_header(KIND_CSR, m.nrows, m.ncols, nnz, payload)
}

/// Zigzag a signed delta into an unsigned code (small magnitudes → small
/// codes, either sign). For `u32` columns the code spans at most 33 bits.
#[inline]
fn zigzag(d: i64) -> u64 {
    ((d << 1) ^ (d >> 63)) as u64
}

/// One pass over `m`'s colidx computing the packed bit width: the maximum
/// zigzag-code bit length over all per-row deltas (0 when every delta is
/// zero, i.e. empty or all-zero-column segments). Never exceeds
/// [`PACKED_WIDTH_MAX`].
fn packed_width(m: &Csr) -> u32 {
    let mut max_code: u64 = 0;
    for r in 0..m.nrows {
        let mut prev: i64 = 0;
        for &c in &m.colidx[m.rowptr[r]..m.rowptr[r + 1]] {
            let code = zigzag(c as i64 - prev);
            max_code = max_code.max(code);
            prev = c as i64;
        }
    }
    64 - max_code.leading_zeros()
}

/// Exact encoded size of `m` as a [`KIND_CSR_PACKED`] record — the packed
/// analog of [`encoded_len`], costing one delta pass and no encode. The
/// `Auto` policy compares this against the raw size to pick per segment.
pub fn encoded_packed_len(m: &Csr) -> u64 {
    let nnz = m.nnz() as u64;
    // nnz counts materialized u32s, so nnz·33 bits cannot overflow u64.
    let words = (nnz * packed_width(m) as u64).div_ceil(64);
    HEADER_BYTES as u64 + (m.nrows as u64 + 1) * 8 + 8 + words * 8 + nnz * 4
}

/// Encode a CSR segment as a [`KIND_CSR_PACKED`] record: rowptr and vals
/// sections identical to [`encode_segment`], colidx replaced by per-row
/// zigzag deltas bitpacked LSB-first at one per-segment width. Like every
/// encoder here it is deterministic (golden-vector pinned), and
/// `decode(encode_packed(m)) == m` exactly — the colidx values round-trip
/// losslessly, so the packed store stays byte-identical at the matrix
/// level to the raw store.
pub fn encode_segment_packed(m: &Csr) -> Vec<u8> {
    let nnz = m.nnz();
    let w = packed_width(m);
    let words = ((nnz as u64 * w as u64).div_ceil(64)) as usize;
    let mut payload = Vec::with_capacity((m.nrows + 1) * 8 + 8 + words * 8 + nnz * 4);
    for &p in &m.rowptr {
        put_u64(&mut payload, p as u64);
    }
    // Width byte + 7 zero pad bytes keep the word stream (and therefore
    // the trailing vals section) 8-byte aligned relative to the payload.
    payload.push(w as u8);
    payload.extend_from_slice(&[0u8; 7]);
    let mut acc: u64 = 0;
    let mut acc_bits: u32 = 0;
    for r in 0..m.nrows {
        let mut prev: i64 = 0;
        for &c in &m.colidx[m.rowptr[r]..m.rowptr[r + 1]] {
            let code = zigzag(c as i64 - prev);
            prev = c as i64;
            if w == 0 {
                continue; // every code is 0: the stream carries no bits
            }
            acc |= code << acc_bits;
            if acc_bits + w >= 64 {
                put_u64(&mut payload, acc);
                // acc_bits ≥ 64 − w ≥ 31 here (w ≤ 33), so the shift is
                // in range; codes that end exactly on the boundary leave 0.
                acc = code >> (64 - acc_bits);
                acc_bits = acc_bits + w - 64;
            } else {
                acc_bits += w;
            }
        }
    }
    if acc_bits > 0 {
        put_u64(&mut payload, acc);
    }
    for &v in &m.vals {
        put_u32(&mut payload, v.to_bits());
    }
    debug_assert_eq!(payload.len() as u64, encoded_packed_len(m) - HEADER_BYTES as u64);

    seal_header(KIND_CSR_PACKED, m.nrows, m.ncols, nnz, payload)
}

/// Encode `m` under an explicit [`SegEncoding`] policy. Returns the bytes
/// and the record kind actually chosen (`Auto` resolves per segment).
pub fn encode_segment_with(m: &Csr, enc: SegEncoding) -> (Vec<u8>, u32) {
    match enc {
        SegEncoding::Raw => (encode_segment(m), KIND_CSR),
        SegEncoding::Packed => (encode_segment_packed(m), KIND_CSR_PACKED),
        SegEncoding::Auto => {
            if encoded_packed_len(m) < encoded_len(m.nrows, m.nnz()) {
                (encode_segment_packed(m), KIND_CSR_PACKED)
            } else {
                (encode_segment(m), KIND_CSR)
            }
        }
    }
}

/// [`write_segment`] under an explicit encoding policy. Returns the bytes
/// written and the record kind chosen (recorded in the store manifest so
/// rebuilds can reproduce the file byte-for-byte).
pub fn write_segment_encoded(
    path: &Path,
    m: &Csr,
    enc: SegEncoding,
) -> Result<(u64, u32), SegioError> {
    let (buf, kind) = encode_segment_with(m, enc);
    let mut f = std::fs::File::create(path)
        .map_err(|e| SegioError::Io(format!("create {}: {e}", path.display())))?;
    f.write_all(&buf).map_err(|e| SegioError::Io(format!("write {}: {e}", path.display())))?;
    Ok((buf.len() as u64, kind))
}

/// Prepend and seal the common 64-byte header over a finished payload.
/// Shared by both record kinds; `nnz` is 0 for panels.
fn seal_header(kind: u32, nrows: usize, ncols: usize, nnz: usize, payload: Vec<u8>) -> Vec<u8> {
    let mut buf = Vec::with_capacity(HEADER_BYTES + payload.len());
    buf.extend_from_slice(&MAGIC);
    put_u32(&mut buf, FORMAT_VERSION);
    put_u32(&mut buf, kind);
    put_u64(&mut buf, nrows as u64);
    put_u64(&mut buf, ncols as u64);
    put_u64(&mut buf, nnz as u64);
    put_u64(&mut buf, payload.len() as u64);
    put_u64(&mut buf, fnv1a64(&payload));
    let header_sum = fnv1a64(&buf);
    put_u64(&mut buf, header_sum);
    debug_assert_eq!(buf.len(), HEADER_BYTES);
    buf.extend_from_slice(&payload);
    buf
}

/// Verify the layout-order header prefix every record kind shares: size,
/// magic, version, record kind, header checksum. Returns nothing — the
/// caller re-reads the count fields it needs.
fn check_header(buf: &[u8], expect_kind: u32) -> Result<(), SegioError> {
    check_header_family(buf, &[expect_kind], expect_kind).map(|_| ())
}

/// Family variant of [`check_header`]: any kind in `accept` passes and is
/// returned; any other kind reports [`SegioError::WrongKind`] against
/// `expected` — the family's canonical kind, so pre-packed error contracts
/// (a panel fed to the CSR decoder names [`KIND_CSR`]) are unchanged.
fn check_header_family(buf: &[u8], accept: &[u32], expected: u32) -> Result<u32, SegioError> {
    if buf.len() < HEADER_BYTES {
        return Err(SegioError::Truncated { need: HEADER_BYTES as u64, got: buf.len() as u64 });
    }
    if buf[0..8] != MAGIC {
        return Err(SegioError::BadMagic);
    }
    let version = get_u32(buf, 8);
    if version != FORMAT_VERSION {
        return Err(SegioError::WrongVersion { found: version, expected: FORMAT_VERSION });
    }
    let kind = get_u32(buf, 12);
    if !accept.contains(&kind) {
        return Err(SegioError::WrongKind { found: kind, expected });
    }
    let stored_header_sum = get_u64(buf, 56);
    let computed_header_sum = fnv1a64(&buf[0..56]);
    if stored_header_sum != computed_header_sum {
        return Err(SegioError::HeaderChecksum {
            stored: stored_header_sum,
            computed: computed_header_sum,
        });
    }
    Ok(kind)
}

/// Decode a segment buffer back into a [`Csr`], verifying magic, version,
/// both checksums, section lengths, and the CSR invariants. The exact
/// inverse of [`encode_segment`]: `decode(encode(m)) == m` for every valid
/// CSR (property-tested across all operand families). Allocates fresh
/// section vectors; the streaming hot loop uses [`decode_segment_into`]
/// with recycled scratch instead.
pub fn decode_segment(buf: &[u8]) -> Result<Csr, SegioError> {
    let mut m = Csr::empty(0, 0);
    decode_segment_into(buf, &mut m)?;
    Ok(m)
}

/// [`decode_segment`] into caller-owned scratch: `out`'s section vectors
/// are cleared and refilled in place, so a decode whose sections fit the
/// scratch capacity performs **zero heap allocations** — the per-segment
/// contract of the recycled staging path (`rust/tests/alloc_free.rs`).
/// Verification is identical to [`decode_segment`]. On error `out` is
/// reset to a valid empty 0×0 matrix (never left holding partial
/// sections).
pub fn decode_segment_into(buf: &[u8], out: &mut Csr) -> Result<(), SegioError> {
    let result = decode_into_raw(buf, out);
    if result.is_err() {
        out.nrows = 0;
        out.ncols = 0;
        out.rowptr.clear();
        out.rowptr.push(0);
        out.colidx.clear();
        out.vals.clear();
    }
    result
}

/// Decode body: clears and refills `out`; may leave it partially written
/// on error (the public wrapper resets it). Accepts both CSR record kinds
/// — raw and packed decode to the identical matrix, so callers never need
/// to know which encoding a store chose.
fn decode_into_raw(buf: &[u8], out: &mut Csr) -> Result<(), SegioError> {
    out.nrows = 0;
    out.ncols = 0;
    out.rowptr.clear();
    out.colidx.clear();
    out.vals.clear();
    let kind = check_header_family(buf, &[KIND_CSR, KIND_CSR_PACKED], KIND_CSR)?;
    let nrows64 = get_u64(buf, 16);
    let ncols64 = get_u64(buf, 24);
    let nnz64 = get_u64(buf, 32);
    let payload_len = get_u64(buf, 40);
    // Checked arithmetic: a crafted header with correctly re-sealed
    // checksums and astronomical counts must surface a typed error, not a
    // wrapped-multiply false match followed by a capacity-overflow abort.
    let overflow = || {
        SegioError::InvalidCsr(format!(
            "nrows={nrows64} / nnz={nnz64} overflow the addressable payload size"
        ))
    };
    let rowptr_bytes =
        nrows64.checked_add(1).and_then(|r| r.checked_mul(8)).ok_or_else(overflow)?;
    if kind == KIND_CSR {
        let want_payload =
            nnz64.checked_mul(8).and_then(|z| rowptr_bytes.checked_add(z)).ok_or_else(overflow)?;
        if payload_len != want_payload {
            return Err(SegioError::InvalidCsr(format!(
                "payload length {payload_len} inconsistent with nrows={nrows64} nnz={nnz64} \
                 (expected {want_payload})"
            )));
        }
    } else {
        // Packed: the exact payload length depends on the bit width stored
        // *inside* the payload, so only the width-independent floor
        // (rowptr + width word + vals) is checkable here — the exact check
        // runs in `unpack_colidx` once the width byte is in hand.
        let min_payload = nnz64
            .checked_mul(4)
            .and_then(|v| rowptr_bytes.checked_add(8)?.checked_add(v))
            .ok_or_else(overflow)?;
        if payload_len < min_payload {
            return Err(SegioError::InvalidCsr(format!(
                "payload length {payload_len} below the packed minimum {min_payload} \
                 for nrows={nrows64} nnz={nnz64}"
            )));
        }
    }
    let need = (HEADER_BYTES as u64).checked_add(payload_len).unwrap_or(u64::MAX);
    if (buf.len() as u64) < need {
        return Err(SegioError::Truncated { need, got: buf.len() as u64 });
    }
    // The truncation check bounds the *payload* by the real buffer size,
    // but on 32-bit targets a count near `u64::MAX` would still wrap a
    // bare `as usize` cast (ncols is not even part of the payload bound),
    // so every narrowing goes through `try_from` with a typed error.
    let narrow = |v: u64, what: &str| {
        usize::try_from(v).map_err(|_| {
            SegioError::InvalidCsr(format!("{what} {v} exceeds this platform's address space"))
        })
    };
    let nrows = narrow(nrows64, "nrows")?;
    let ncols = narrow(ncols64, "ncols")?;
    let nnz = narrow(nnz64, "nnz")?;
    let payload_usize = narrow(payload_len, "payload length")?;
    let payload = &buf[HEADER_BYTES..HEADER_BYTES + payload_usize];
    let stored_payload_sum = get_u64(buf, 48);
    let computed_payload_sum = fnv1a64(payload);
    if stored_payload_sum != computed_payload_sum {
        return Err(SegioError::PayloadChecksum {
            stored: stored_payload_sum,
            computed: computed_payload_sum,
        });
    }

    PAYLOAD_COPIES.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    let mut off = 0usize;
    out.rowptr.reserve(nrows + 1);
    for _ in 0..=nrows {
        out.rowptr.push(get_u64(payload, off) as usize);
        off += 8;
    }
    out.colidx.reserve(nnz);
    if kind == KIND_CSR {
        for _ in 0..nnz {
            out.colidx.push(get_u32(payload, off));
            off += 4;
        }
    } else {
        off = unpack_colidx(payload, off, nrows, nnz, out)?;
    }
    out.vals.reserve(nnz);
    for _ in 0..nnz {
        out.vals.push(f32::from_bits(get_u32(payload, off)));
        off += 4;
    }
    debug_assert_eq!(off, payload.len());
    out.nrows = nrows;
    out.ncols = ncols;
    out.validate().map_err(SegioError::InvalidCsr)
}

/// Decode a [`KIND_CSR_PACKED`] colidx section (width byte + pad +
/// bitstream) into `out.colidx`, starting at payload offset `off` (just
/// past the rowptr section, which must already be in `out.rowptr` — the
/// row boundaries drive the per-row delta resets). Returns the byte offset
/// of the vals section. Every defect a crafted record could carry here —
/// out-of-range width, dirty pad bytes, a payload length inconsistent
/// with the width, or deltas that walk outside the `u32` column range —
/// is a typed [`SegioError::InvalidCsr`].
fn unpack_colidx(
    payload: &[u8],
    off: usize,
    nrows: usize,
    nnz: usize,
    out: &mut Csr,
) -> Result<usize, SegioError> {
    let w = payload[off] as u32;
    if w > PACKED_WIDTH_MAX {
        return Err(SegioError::InvalidCsr(format!(
            "packed colidx bit width {w} exceeds the {PACKED_WIDTH_MAX}-bit delta bound"
        )));
    }
    if payload[off + 1..off + 8].iter().any(|&b| b != 0) {
        return Err(SegioError::InvalidCsr(
            "non-zero pad bytes after the packed colidx width".into(),
        ));
    }
    let words_off = off + 8;
    // u64 math: the word count is derived, not read, so it must not be
    // allowed to wrap a 32-bit usize before the length comparison.
    let words64 = (nnz as u64 * w as u64).div_ceil(64);
    let want = words_off as u64 + words64 * 8 + nnz as u64 * 4;
    if payload.len() as u64 != want {
        return Err(SegioError::InvalidCsr(format!(
            "payload length {} inconsistent with packed bit width {w} (expected {want})",
            payload.len()
        )));
    }
    let mask: u64 = if w == 0 { 0 } else { (1u64 << w) - 1 };
    let mut bitpos: u64 = 0;
    for r in 0..nrows {
        let lo = out.rowptr[r];
        let hi = out.rowptr[r + 1];
        // Bounds before bits: the bitstream cursor below is only in range
        // because every row interval stays inside [0, nnz] and monotone.
        if hi < lo || hi > nnz {
            return Err(SegioError::InvalidCsr(format!(
                "rowptr row {r} interval [{lo}, {hi}) is not monotone within nnz={nnz}"
            )));
        }
        let mut prev: i64 = 0;
        for _ in lo..hi {
            let code = if w == 0 {
                0
            } else {
                let wi = (bitpos / 64) as usize;
                let bo = (bitpos % 64) as u32;
                let mut v = get_u64(payload, words_off + wi * 8) >> bo;
                if bo + w > 64 {
                    v |= get_u64(payload, words_off + (wi + 1) * 8) << (64 - bo);
                }
                bitpos += w as u64;
                v & mask
            };
            // Un-zigzag; |delta| < 2^33 and 0 ≤ prev ≤ u32::MAX, so the
            // i64 sum cannot overflow — only leave the u32 column range.
            let delta = ((code >> 1) as i64) ^ -((code & 1) as i64);
            let cur = prev + delta;
            if !(0..=u32::MAX as i64).contains(&cur) {
                return Err(SegioError::InvalidCsr(format!(
                    "packed colidx delta leaves the u32 range at row {r} (decoded {cur})"
                )));
            }
            out.colidx.push(cur as u32);
            prev = cur;
        }
    }
    Ok(words_off + words64 as usize * 8)
}

/// Write one encoded segment to `path`. Returns the bytes written.
pub fn write_segment(path: &Path, m: &Csr) -> Result<u64, SegioError> {
    let buf = encode_segment(m);
    let mut f = std::fs::File::create(path)
        .map_err(|e| SegioError::Io(format!("create {}: {e}", path.display())))?;
    f.write_all(&buf).map_err(|e| SegioError::Io(format!("write {}: {e}", path.display())))?;
    Ok(buf.len() as u64)
}

/// Read and decode one segment file. Returns the matrix and the file's
/// byte count (the *measured* I/O the staging layer charges, as opposed
/// to the planner's estimate).
pub fn read_segment(path: &Path) -> Result<(Csr, u64), SegioError> {
    let mut scratch = Vec::new();
    let mut m = Csr::empty(0, 0);
    let bytes = read_segment_into(path, &mut scratch, &mut m)?;
    Ok((m, bytes))
}

/// [`read_segment`] into caller-owned buffers: the file bytes land in
/// `scratch` (cleared and sized to the file) and the decoded matrix in
/// `out`'s recycled sections. Once both have reached the stream's
/// high-water capacity, a read performs no heap allocation beyond the
/// kernel I/O itself — the producer-side half of the allocation-free
/// staging contract. Returns the measured file byte count.
pub fn read_segment_into(
    path: &Path,
    scratch: &mut Vec<u8>,
    out: &mut Csr,
) -> Result<u64, SegioError> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| SegioError::Io(format!("open {}: {e}", path.display())))?;
    // Size from metadata + read_exact (not read_to_end): read_to_end's
    // EOF probe can reallocate even when the scratch capacity already
    // covers the file, which would break the zero-allocation steady state.
    let len = f
        .metadata()
        .map_err(|e| SegioError::Io(format!("stat {}: {e}", path.display())))?
        .len() as usize;
    // resize without a prior clear: read_exact overwrites every byte, so
    // only the grown tail (usually empty in steady state) needs the
    // zero-fill — no full memset per staged segment.
    scratch.resize(len, 0);
    f.read_exact(scratch)
        .map_err(|e| SegioError::Io(format!("read {}: {e}", path.display())))?;
    decode_segment_into(scratch, out)?;
    Ok(len as u64)
}

// ------------------------------------------------- borrowed (mmap) views

/// A fully validated borrowed view of a raw ([`KIND_CSR`]) segment record:
/// the zero-copy counterpart of [`decode_segment`]. Constructed only by
/// [`decode_segment_ref`], which runs the *same* checks as the copying
/// decoder (header, payload checksum, CSR invariants) — holding a
/// `SegmentRef` is proof the bytes are a valid segment, it just leaves the
/// O(nnz) sections where they are (typically a page-cache-backed mapping).
#[derive(Debug, Clone, Copy)]
pub struct SegmentRef<'a> {
    /// Row count.
    pub nrows: usize,
    /// Column count.
    pub ncols: usize,
    nnz: usize,
    payload: &'a [u8],
}

impl<'a> SegmentRef<'a> {
    /// Stored non-zero count.
    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// `rowptr[i]` (decoded from the payload on each call; the mapped-read
    /// path materializes the whole rowptr once into recycled scratch via
    /// [`SegmentRef::fill_rowptr`] instead of calling this per row).
    pub fn rowptr(&self, i: usize) -> usize {
        debug_assert!(i <= self.nrows);
        get_u64(self.payload, i * 8) as usize
    }

    /// Materialize the rowptr section into caller-recycled scratch
    /// (cleared and refilled; zero allocations once capacity has grown).
    /// Rowptr is O(nrows) — a small fraction of a segment — and decoding
    /// it once keeps the per-row kernel free of byte-twiddling; only the
    /// O(nnz) colidx/vals sections stay borrowed.
    pub fn fill_rowptr(&self, out: &mut Vec<usize>) {
        out.clear();
        out.reserve(self.nrows + 1);
        for i in 0..=self.nrows {
            out.push(get_u64(self.payload, i * 8) as usize);
        }
    }

    /// The colidx section as a borrowed `&[u32]`, when the platform allows
    /// viewing it in place: little-endian byte order and a 4-aligned
    /// section start. An mmap'd record always qualifies on little-endian
    /// targets — the mapping is page-aligned and the section offset
    /// `64 + (nrows+1)·8` is a multiple of 8. `None` means the caller must
    /// fall back to a copy decode.
    pub fn colidx_u32(&self) -> Option<&'a [u32]> {
        let bytes = &self.payload[(self.nrows + 1) * 8..(self.nrows + 1) * 8 + self.nnz * 4];
        borrow_le_slice::<u32>(bytes, self.nnz)
    }

    /// The vals section as a borrowed `&[f32]` (same conditions as
    /// [`SegmentRef::colidx_u32`]).
    pub fn vals_f32(&self) -> Option<&'a [f32]> {
        let start = (self.nrows + 1) * 8 + self.nnz * 4;
        let bytes = &self.payload[start..start + self.nnz * 4];
        borrow_le_slice::<f32>(bytes, self.nnz)
    }
}

/// Reinterpret a little-endian byte section as `&[T]` when alignment and
/// target byte order allow it. `T` is only ever a 4-byte primitive here
/// (`u32` / `f32`); the length is in elements. Crate-visible so the
/// segment store can re-derive section slices from a held mapping + the
/// offsets it recorded at map time (a `SegmentRef` cannot be stored next
/// to the mapping it borrows).
pub(crate) fn borrow_le_slice<T>(bytes: &[u8], len: usize) -> Option<&[T]> {
    debug_assert_eq!(bytes.len(), len * std::mem::size_of::<T>());
    if cfg!(target_endian = "little") && bytes.as_ptr() as usize % std::mem::align_of::<T>() == 0 {
        // SAFETY: the pointer is aligned for T (checked), the section
        // covers exactly `len` T-sized elements (debug-asserted, and
        // guaranteed by the callers' validated section arithmetic), the
        // borrow inherits the source lifetime, and u32/f32 have no invalid
        // bit patterns.
        Some(unsafe { std::slice::from_raw_parts(bytes.as_ptr() as *const T, len) })
    } else {
        None
    }
}

/// Validate a raw segment record and return a borrowed [`SegmentRef`] —
/// the zero-copy decode used by the mmap read path. Verification is
/// byte-for-byte the same discipline as [`decode_segment`]: magic,
/// version, kind, both checksums, section lengths, and the full CSR
/// invariant walk (`rowptr[0] == 0`, monotone, `rowptr[-1] == nnz`,
/// strictly sorted in-bounds columns) — without materializing a section.
/// Packed records return [`SegioError::WrongKind`]: zero-copy serves the
/// raw layout only, and the store falls back to a copy decode for packed.
pub fn decode_segment_ref(buf: &[u8]) -> Result<SegmentRef<'_>, SegioError> {
    check_header(buf, KIND_CSR)?;
    let nrows64 = get_u64(buf, 16);
    let ncols64 = get_u64(buf, 24);
    let nnz64 = get_u64(buf, 32);
    let payload_len = get_u64(buf, 40);
    let want_payload = nrows64
        .checked_add(1)
        .and_then(|r| r.checked_mul(8))
        .and_then(|r| nnz64.checked_mul(8).and_then(|z| r.checked_add(z)))
        .ok_or_else(|| {
            SegioError::InvalidCsr(format!(
                "nrows={nrows64} / nnz={nnz64} overflow the addressable payload size"
            ))
        })?;
    if payload_len != want_payload {
        return Err(SegioError::InvalidCsr(format!(
            "payload length {payload_len} inconsistent with nrows={nrows64} nnz={nnz64} \
             (expected {want_payload})"
        )));
    }
    let need = (HEADER_BYTES as u64).checked_add(payload_len).unwrap_or(u64::MAX);
    if (buf.len() as u64) < need {
        return Err(SegioError::Truncated { need, got: buf.len() as u64 });
    }
    let narrow = |v: u64, what: &str| {
        usize::try_from(v).map_err(|_| {
            SegioError::InvalidCsr(format!("{what} {v} exceeds this platform's address space"))
        })
    };
    let nrows = narrow(nrows64, "nrows")?;
    let ncols = narrow(ncols64, "ncols")?;
    let nnz = narrow(nnz64, "nnz")?;
    let payload_usize = narrow(payload_len, "payload length")?;
    let payload = &buf[HEADER_BYTES..HEADER_BYTES + payload_usize];
    let stored_payload_sum = get_u64(buf, 48);
    let computed_payload_sum = fnv1a64(payload);
    if stored_payload_sum != computed_payload_sum {
        return Err(SegioError::PayloadChecksum {
            stored: stored_payload_sum,
            computed: computed_payload_sum,
        });
    }

    // The CSR invariant walk `Csr::validate` performs, off borrowed bytes:
    // the checksum proves the bytes are what was written, this proves what
    // was written is a matrix. O(nnz) like the checksum pass, no copies.
    if get_u64(payload, 0) != 0 {
        return Err(SegioError::InvalidCsr("rowptr[0] != 0".into()));
    }
    if get_u64(payload, nrows * 8) != nnz as u64 {
        return Err(SegioError::InvalidCsr("rowptr[-1] != nnz".into()));
    }
    let colbase = (nrows + 1) * 8;
    for r in 0..nrows {
        let lo = get_u64(payload, r * 8);
        let hi = get_u64(payload, (r + 1) * 8);
        if hi < lo || hi > nnz as u64 {
            return Err(SegioError::InvalidCsr("rowptr not monotone".into()));
        }
        let mut prev: i64 = -1;
        for e in lo..hi {
            let c = get_u32(payload, colbase + e as usize * 4) as i64;
            if c <= prev {
                return Err(SegioError::InvalidCsr(format!(
                    "row {r} columns not strictly sorted"
                )));
            }
            prev = c;
        }
        if prev >= ncols as i64 {
            return Err(SegioError::InvalidCsr(format!(
                "row {r} column {prev} out of bounds"
            )));
        }
    }
    Ok(SegmentRef { nrows, ncols, nnz, payload })
}

/// A validated borrowed view of a [`KIND_PANEL`] record — the panel analog
/// of [`SegmentRef`], used by the mapped panel-chunk path and by chunk
/// assembly (which copies rows straight from the record into their slot in
/// a full panel, with no intermediate `Dense`).
#[derive(Debug, Clone, Copy)]
pub struct PanelRef<'a> {
    /// Row count.
    pub nrows: usize,
    /// Column count (features).
    pub ncols: usize,
    payload: &'a [u8],
}

impl<'a> PanelRef<'a> {
    /// The whole row-major payload as a borrowed `&[f32]`, when alignment
    /// and byte order allow (always, for an mmap'd record on a
    /// little-endian target: the payload starts 64 bytes into a
    /// page-aligned mapping). `None` means use [`PanelRef::fill_into`].
    pub fn data_f32(&self) -> Option<&'a [f32]> {
        borrow_le_slice::<f32>(self.payload, self.nrows * self.ncols)
    }

    /// Copy-decode the payload into `out`, which must be exactly
    /// `nrows × ncols` long — the alignment-free fallback, and the chunk
    /// assembler's row-slot writer.
    pub fn fill_into(&self, out: &mut [f32]) {
        assert_eq!(out.len(), self.nrows * self.ncols, "destination/panel shape mismatch");
        if let Some(src) = self.data_f32() {
            out.copy_from_slice(src);
        } else {
            for (i, slot) in out.iter_mut().enumerate() {
                *slot = f32::from_bits(get_u32(self.payload, i * 4));
            }
        }
    }
}

/// Validate a panel record and return a borrowed [`PanelRef`] — the same
/// checks as [`decode_panel`], no materialization (f32 payloads have no
/// structural invariants beyond their length, so the checksum pass is the
/// whole walk).
pub fn decode_panel_ref(buf: &[u8]) -> Result<PanelRef<'_>, SegioError> {
    check_header(buf, KIND_PANEL)?;
    let nrows64 = get_u64(buf, 16);
    let ncols64 = get_u64(buf, 24);
    let nnz64 = get_u64(buf, 32);
    let payload_len = get_u64(buf, 40);
    if nnz64 != 0 {
        return Err(SegioError::InvalidPanel(format!(
            "panel records must have a zero nnz field, got {nnz64}"
        )));
    }
    let want_payload =
        nrows64.checked_mul(ncols64).and_then(|n| n.checked_mul(4)).ok_or_else(|| {
            SegioError::InvalidPanel(format!(
                "nrows={nrows64} × ncols={ncols64} overflows the addressable payload size"
            ))
        })?;
    if payload_len != want_payload {
        return Err(SegioError::InvalidPanel(format!(
            "payload length {payload_len} inconsistent with nrows={nrows64} ncols={ncols64} \
             (expected {want_payload})"
        )));
    }
    let need = (HEADER_BYTES as u64).checked_add(payload_len).unwrap_or(u64::MAX);
    if (buf.len() as u64) < need {
        return Err(SegioError::Truncated { need, got: buf.len() as u64 });
    }
    let narrow = |v: u64, what: &str| {
        usize::try_from(v).map_err(|_| {
            SegioError::InvalidPanel(format!("{what} {v} exceeds this platform's address space"))
        })
    };
    let nrows = narrow(nrows64, "nrows")?;
    let ncols = narrow(ncols64, "ncols")?;
    let payload_usize = narrow(payload_len, "payload length")?;
    let payload = &buf[HEADER_BYTES..HEADER_BYTES + payload_usize];
    let stored_payload_sum = get_u64(buf, 48);
    let computed_payload_sum = fnv1a64(payload);
    if stored_payload_sum != computed_payload_sum {
        return Err(SegioError::PayloadChecksum {
            stored: stored_payload_sum,
            computed: computed_payload_sum,
        });
    }
    Ok(PanelRef { nrows, ncols, payload })
}

// --------------------------------------------------- dense-panel records

/// Encode a dense feature panel into the on-disk byte format
/// ([`KIND_PANEL`]). Deterministic and exact: the payload is the row-major
/// f32 *bit patterns*, so `decode(encode(p)) == p` down to the last bit —
/// the property that keeps a panel-spilling multi-layer pass byte-identical
/// to one that holds every intermediate panel in host RAM.
pub fn encode_panel(p: &Dense) -> Vec<u8> {
    let mut payload = Vec::with_capacity(p.data.len() * 4);
    for &v in &p.data {
        put_u32(&mut payload, v.to_bits());
    }
    seal_header(KIND_PANEL, p.nrows, p.ncols, 0, payload)
}

/// Decode a panel buffer back into a [`Dense`], verifying magic, version,
/// record kind, both checksums, and the dimension/payload consistency.
/// The exact inverse of [`encode_panel`]. Allocates a fresh data vector;
/// the pipeline's panel tier uses [`decode_panel_into`] with recycled
/// scratch instead.
pub fn decode_panel(buf: &[u8]) -> Result<Dense, SegioError> {
    let mut p = Dense::zeros(0, 0);
    decode_panel_into(buf, &mut p)?;
    Ok(p)
}

/// [`decode_panel`] into caller-owned scratch: `out.data` is cleared and
/// refilled in place, so a decode that fits the scratch capacity performs
/// zero heap allocations. On error `out` is reset to a valid empty 0×0
/// panel (never left holding partial data).
pub fn decode_panel_into(buf: &[u8], out: &mut Dense) -> Result<(), SegioError> {
    let result = decode_panel_raw(buf, out);
    if result.is_err() {
        out.nrows = 0;
        out.ncols = 0;
        out.data.clear();
    }
    result
}

/// Decode body: clears and refills `out`; may leave it partially written
/// on error (the public wrapper resets it).
fn decode_panel_raw(buf: &[u8], out: &mut Dense) -> Result<(), SegioError> {
    out.nrows = 0;
    out.ncols = 0;
    out.data.clear();
    check_header(buf, KIND_PANEL)?;
    let nrows64 = get_u64(buf, 16);
    let ncols64 = get_u64(buf, 24);
    let nnz64 = get_u64(buf, 32);
    let payload_len = get_u64(buf, 40);
    if nnz64 != 0 {
        return Err(SegioError::InvalidPanel(format!(
            "panel records must have a zero nnz field, got {nnz64}"
        )));
    }
    // Checked arithmetic: crafted dimensions with re-sealed checksums must
    // surface a typed error, not a wrapped-multiply false match.
    let want_payload = nrows64
        .checked_mul(ncols64)
        .and_then(|n| n.checked_mul(4))
        .ok_or_else(|| {
            SegioError::InvalidPanel(format!(
                "nrows={nrows64} × ncols={ncols64} overflows the addressable payload size"
            ))
        })?;
    if payload_len != want_payload {
        return Err(SegioError::InvalidPanel(format!(
            "payload length {payload_len} inconsistent with nrows={nrows64} ncols={ncols64} \
             (expected {want_payload})"
        )));
    }
    let need = (HEADER_BYTES as u64).checked_add(payload_len).unwrap_or(u64::MAX);
    if (buf.len() as u64) < need {
        return Err(SegioError::Truncated { need, got: buf.len() as u64 });
    }
    // The truncation check bounds the payload by the real buffer size, but
    // the raw counts can still exceed a 32-bit address space — narrow them
    // with `try_from` so a crafted header yields the typed error there too.
    let narrow = |v: u64, what: &str| {
        usize::try_from(v).map_err(|_| {
            SegioError::InvalidPanel(format!("{what} {v} exceeds this platform's address space"))
        })
    };
    let nrows = narrow(nrows64, "nrows")?;
    let ncols = narrow(ncols64, "ncols")?;
    let payload_usize = narrow(payload_len, "payload length")?;
    let payload = &buf[HEADER_BYTES..HEADER_BYTES + payload_usize];
    let stored_payload_sum = get_u64(buf, 48);
    let computed_payload_sum = fnv1a64(payload);
    if stored_payload_sum != computed_payload_sum {
        return Err(SegioError::PayloadChecksum {
            stored: stored_payload_sum,
            computed: computed_payload_sum,
        });
    }
    PAYLOAD_COPIES.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
    // want_payload == payload_len fits usize, so the element count (a
    // quarter of it) does too — reuse the checked product, never re-multiply.
    let n = payload_usize / 4;
    out.data.reserve(n);
    for i in 0..n {
        out.data.push(f32::from_bits(get_u32(payload, i * 4)));
    }
    out.nrows = nrows;
    out.ncols = ncols;
    Ok(())
}

/// Write one encoded panel to `path`. Returns the bytes written.
pub fn write_panel(path: &Path, p: &Dense) -> Result<u64, SegioError> {
    let buf = encode_panel(p);
    let mut f = std::fs::File::create(path)
        .map_err(|e| SegioError::Io(format!("create {}: {e}", path.display())))?;
    f.write_all(&buf).map_err(|e| SegioError::Io(format!("write {}: {e}", path.display())))?;
    Ok(buf.len() as u64)
}

/// Read and decode one panel file into caller-owned buffers (the panel-tier
/// analog of [`read_segment_into`]): file bytes land in `scratch`, the
/// decoded panel in `out`'s recycled data vector. Returns the measured
/// file byte count.
pub fn read_panel_into(
    path: &Path,
    scratch: &mut Vec<u8>,
    out: &mut Dense,
) -> Result<u64, SegioError> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| SegioError::Io(format!("open {}: {e}", path.display())))?;
    let len = f
        .metadata()
        .map_err(|e| SegioError::Io(format!("stat {}: {e}", path.display())))?
        .len() as usize;
    scratch.resize(len, 0);
    f.read_exact(scratch)
        .map_err(|e| SegioError::Io(format!("read {}: {e}", path.display())))?;
    decode_panel_into(scratch, out)?;
    Ok(len as u64)
}

// ----------------------------------------------- checkpoint-blob records

/// Exact encoded size of a checkpoint blob with `payload` body bytes —
/// header + opaque payload (the blob analog of [`encoded_len`]).
pub fn encoded_blob_len(payload: usize) -> u64 {
    HEADER_BYTES as u64 + payload as u64
}

/// Encode an opaque byte payload as a [`KIND_CHECK`] record: the shared
/// magic/version/checksum header over a caller-defined body. All three
/// count fields are zero — a blob has no matrix shape; its only length is
/// the payload-length field itself. Deterministic: the same bytes always
/// produce the same record.
pub fn encode_blob(payload: &[u8]) -> Vec<u8> {
    seal_header(KIND_CHECK, 0, 0, 0, payload.to_vec())
}

/// Decode a [`KIND_CHECK`] record back to its payload bytes, verifying
/// magic, version, record kind, both checksums, the zero count fields, and
/// the payload length. The exact inverse of [`encode_blob`]. Feeding a
/// segment or panel file here is a [`SegioError::WrongKind`], never a
/// misread.
pub fn decode_blob(buf: &[u8]) -> Result<Vec<u8>, SegioError> {
    check_header(buf, KIND_CHECK)?;
    let nrows64 = get_u64(buf, 16);
    let ncols64 = get_u64(buf, 24);
    let nnz64 = get_u64(buf, 32);
    if (nrows64, ncols64, nnz64) != (0, 0, 0) {
        return Err(SegioError::InvalidBlob(format!(
            "blob records must have zero count fields, got nrows={nrows64} ncols={ncols64} \
             nnz={nnz64}"
        )));
    }
    let payload_len = get_u64(buf, 40);
    let need = (HEADER_BYTES as u64).checked_add(payload_len).unwrap_or(u64::MAX);
    if (buf.len() as u64) < need {
        return Err(SegioError::Truncated { need, got: buf.len() as u64 });
    }
    let payload_usize = usize::try_from(payload_len).map_err(|_| {
        SegioError::InvalidBlob(format!(
            "payload length {payload_len} exceeds this platform's address space"
        ))
    })?;
    let payload = &buf[HEADER_BYTES..HEADER_BYTES + payload_usize];
    let stored_payload_sum = get_u64(buf, 48);
    let computed_payload_sum = fnv1a64(payload);
    if stored_payload_sum != computed_payload_sum {
        return Err(SegioError::PayloadChecksum {
            stored: stored_payload_sum,
            computed: computed_payload_sum,
        });
    }
    Ok(payload.to_vec())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn example_csr() -> Csr {
        // [[1, 0, 2],
        //  [0, 3, 0]]
        let mut coo = Coo::new(2, 3);
        coo.push(0, 0, 1.0);
        coo.push(0, 2, 2.0);
        coo.push(1, 1, 3.0);
        coo.to_csr()
    }

    #[test]
    fn golden_encoding_is_byte_stable() {
        // Golden vector computed independently (Python struct/FNV-1a) from
        // the layout spec — pins the format so an accidental layout change
        // cannot slip through as "roundtrip still works".
        let want: [u8; 112] = [
            65, 73, 82, 69, 83, 83, 69, 71, 1, 0, 0, 0, 0, 0, 0, 0, 2, 0, 0, 0, 0, 0, 0, 0, 3, 0,
            0, 0, 0, 0, 0, 0, 3, 0, 0, 0, 0, 0, 0, 0, 48, 0, 0, 0, 0, 0, 0, 0, 102, 36, 155, 56,
            151, 250, 16, 101, 36, 89, 208, 127, 127, 42, 60, 48, 0, 0, 0, 0, 0, 0, 0, 0, 2, 0, 0,
            0, 0, 0, 0, 0, 3, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 2, 0, 0, 0, 1, 0, 0, 0, 0, 0, 128,
            63, 0, 0, 0, 64, 0, 0, 64, 64,
        ];
        let got = encode_segment(&example_csr());
        assert_eq!(got, want.to_vec());
        assert_eq!(got.len() as u64, encoded_len(2, 3));
    }

    #[test]
    fn roundtrip_example() {
        let m = example_csr();
        assert_eq!(decode_segment(&encode_segment(&m)).unwrap(), m);
    }

    #[test]
    fn roundtrip_empty_shapes() {
        for m in [Csr::empty(0, 0), Csr::empty(0, 7), Csr::empty(5, 0), Csr::empty(3, 4)] {
            let buf = encode_segment(&m);
            assert_eq!(buf.len() as u64, encoded_len(m.nrows, 0));
            assert_eq!(decode_segment(&buf).unwrap(), m);
        }
    }

    #[test]
    fn fnv_known_vectors() {
        // Reference values of FNV-1a 64.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn rejects_every_defect_with_the_right_variant() {
        let good = encode_segment(&example_csr());

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        assert_eq!(decode_segment(&bad_magic), Err(SegioError::BadMagic));

        let mut wrong_version = good.clone();
        wrong_version[8] = 2;
        // Re-seal the header so the version check (not the checksum) fires.
        let sum = fnv1a64(&wrong_version[0..56]);
        wrong_version[56..64].copy_from_slice(&sum.to_le_bytes());
        assert_eq!(
            decode_segment(&wrong_version),
            Err(SegioError::WrongVersion { found: 2, expected: FORMAT_VERSION })
        );

        let mut bad_header = good.clone();
        bad_header[20] ^= 0x01; // nrows field
        assert!(matches!(decode_segment(&bad_header), Err(SegioError::HeaderChecksum { .. })));

        let mut bad_payload = good.clone();
        *bad_payload.last_mut().unwrap() ^= 0x01;
        assert!(matches!(decode_segment(&bad_payload), Err(SegioError::PayloadChecksum { .. })));

        assert!(matches!(
            decode_segment(&good[..good.len() - 1]),
            Err(SegioError::Truncated { .. })
        ));
        assert!(matches!(decode_segment(&good[..10]), Err(SegioError::Truncated { .. })));
        assert!(matches!(decode_segment(b""), Err(SegioError::Truncated { .. })));
    }

    #[test]
    fn huge_header_counts_are_rejected_not_panicking() {
        // A crafted header with re-sealed checksums and astronomical
        // counts: the wrapped multiply would otherwise make the payload
        // consistency check pass and the rowptr allocation abort.
        let mut buf = encode_segment(&example_csr());
        buf[16..24].copy_from_slice(&(1u64 << 61).to_le_bytes()); // nrows
        buf[32..40].copy_from_slice(&0u64.to_le_bytes()); // nnz
        buf[40..48].copy_from_slice(&8u64.to_le_bytes()); // payload_len
        let sum = fnv1a64(&buf[0..56]);
        buf[56..64].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode_segment(&buf), Err(SegioError::InvalidCsr(_))));

        // Large-but-not-overflowing counts stop at the truncation check,
        // before any allocation.
        let mut buf = encode_segment(&example_csr());
        let nrows = 1u64 << 40;
        buf[16..24].copy_from_slice(&nrows.to_le_bytes());
        buf[32..40].copy_from_slice(&0u64.to_le_bytes());
        buf[40..48].copy_from_slice(&((nrows + 1) * 8).to_le_bytes());
        let sum = fnv1a64(&buf[0..56]);
        buf[56..64].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode_segment(&buf), Err(SegioError::Truncated { .. })));
    }

    #[test]
    fn counts_beyond_the_address_space_narrow_with_a_typed_error() {
        // ncols is the one CSR count the payload-length consistency check
        // does not bound, so a crafted header can smuggle an arbitrary
        // 64-bit value through every earlier guard. A bare `as usize` cast
        // wrapped it silently on 32-bit targets; the narrowing now goes
        // through `try_from`, so any unrepresentable count is the typed
        // error and a representable one decodes unchanged.
        let mut buf = encode_segment(&example_csr());
        buf[24..32].copy_from_slice(&u64::MAX.to_le_bytes()); // ncols
        let sum = fnv1a64(&buf[0..56]);
        buf[56..64].copy_from_slice(&sum.to_le_bytes());
        let r = decode_segment(&buf);
        if usize::try_from(u64::MAX).is_err() {
            // 32-bit target: rejected before any section is read.
            assert!(matches!(r, Err(SegioError::InvalidCsr(_))), "{r:?}");
        } else {
            // 64-bit target: the value is representable — the matrix is
            // simply astronomically wide, and nothing wrapped.
            assert_eq!(r.unwrap().ncols, u64::MAX as usize);
        }
    }

    #[test]
    fn rejects_semantically_invalid_csr() {
        // Non-monotone rowptr survives both checksums (they protect bytes,
        // not invariants) and must be caught by CSR validation.
        let bad =
            Csr { nrows: 2, ncols: 2, rowptr: vec![0, 2, 1], colidx: vec![0], vals: vec![1.0] };
        // encode_segment reads fields directly, so it happily serializes it.
        let buf = encode_segment(&bad);
        assert!(matches!(decode_segment(&buf), Err(SegioError::InvalidCsr(_))));
    }

    #[test]
    fn file_roundtrip() {
        let dir = crate::testing::TempDir::new("segio-unit");
        let path = dir.path().join("seg.bin");
        let m = example_csr();
        let written = write_segment(&path, &m).unwrap();
        let (back, read) = read_segment(&path).unwrap();
        assert_eq!(back, m);
        assert_eq!(written, read);
        assert!(matches!(
            read_segment(&dir.path().join("missing.bin")),
            Err(SegioError::Io(_))
        ));
    }

    fn example_panel() -> Dense {
        Dense::from_vec(2, 3, vec![1.0, 2.0, 3.0, -4.0, 0.5, 6.0])
    }

    #[test]
    fn golden_panel_encoding_is_byte_stable() {
        // Golden vector computed independently (Python struct/FNV-1a) from
        // the layout spec — pins the panel record kind the same way the
        // CSR golden vector pins segments.
        let want: [u8; 88] = [
            65, 73, 82, 69, 83, 83, 69, 71, 1, 0, 0, 0, 1, 0, 0, 0, 2, 0, 0, 0, 0, 0, 0, 0, 3, 0,
            0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 0, 24, 0, 0, 0, 0, 0, 0, 0, 69, 185, 8, 35,
            128, 218, 222, 195, 235, 183, 34, 93, 20, 81, 129, 48, 0, 0, 128, 63, 0, 0, 0, 64, 0,
            0, 64, 64, 0, 0, 128, 192, 0, 0, 0, 63, 0, 0, 192, 64,
        ];
        let got = encode_panel(&example_panel());
        assert_eq!(got, want.to_vec());
        assert_eq!(got.len() as u64, encoded_panel_len(2, 3));
    }

    #[test]
    fn panel_roundtrip_is_bit_exact() {
        // Includes values a lossy float path would disturb: subnormals,
        // negative zero, infinities, and an exact NaN bit pattern survive
        // because the payload is raw bit patterns.
        let mut p = example_panel();
        p.data[0] = f32::from_bits(0x0000_0001); // subnormal
        p.data[1] = -0.0;
        p.data[2] = f32::INFINITY;
        let back = decode_panel(&encode_panel(&p)).unwrap();
        assert_eq!(back.nrows, p.nrows);
        assert_eq!(back.ncols, p.ncols);
        for (a, b) in p.data.iter().zip(back.data.iter()) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for p in [Dense::zeros(0, 0), Dense::zeros(0, 7), Dense::zeros(5, 0)] {
            assert_eq!(decode_panel(&encode_panel(&p)).unwrap(), p);
        }
    }

    #[test]
    fn panel_rejects_every_defect_with_the_right_variant() {
        let good = encode_panel(&example_panel());

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        assert_eq!(decode_panel(&bad_magic), Err(SegioError::BadMagic));

        let mut bad_payload = good.clone();
        *bad_payload.last_mut().unwrap() ^= 0x01;
        assert!(matches!(decode_panel(&bad_payload), Err(SegioError::PayloadChecksum { .. })));

        let mut bad_header = good.clone();
        bad_header[20] ^= 0x01; // nrows field
        assert!(matches!(decode_panel(&bad_header), Err(SegioError::HeaderChecksum { .. })));

        assert!(matches!(decode_panel(&good[..good.len() - 1]), Err(SegioError::Truncated { .. })));
        assert!(matches!(decode_panel(&good[..10]), Err(SegioError::Truncated { .. })));

        // A non-zero nnz slot with a re-sealed checksum is invalid.
        let mut bad_nnz = good.clone();
        bad_nnz[32..40].copy_from_slice(&7u64.to_le_bytes());
        let sum = fnv1a64(&bad_nnz[0..56]);
        bad_nnz[56..64].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode_panel(&bad_nnz), Err(SegioError::InvalidPanel(_))));

        // Overflowing dimensions with re-sealed checksums: typed error,
        // not a wrapped-multiply false match.
        let mut huge = good.clone();
        huge[16..24].copy_from_slice(&(1u64 << 62).to_le_bytes());
        huge[24..32].copy_from_slice(&(1u64 << 62).to_le_bytes());
        let sum = fnv1a64(&huge[0..56]);
        huge[56..64].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode_panel(&huge), Err(SegioError::InvalidPanel(_))));

        // A zero-area panel smuggles an arbitrary row count past the
        // payload-length check (huge × 0 = 0, consistently). The count
        // must narrow via `try_from`: typed rejection where usize cannot
        // hold it, a faithful (not wrapped) value where it can.
        let mut zero_area = good.clone();
        zero_area[16..24].copy_from_slice(&(1u64 << 40).to_le_bytes()); // nrows
        zero_area[24..32].copy_from_slice(&0u64.to_le_bytes()); // ncols
        zero_area[40..48].copy_from_slice(&0u64.to_le_bytes()); // payload_len
        let psum = fnv1a64(&[]);
        zero_area[48..56].copy_from_slice(&psum.to_le_bytes());
        let sum = fnv1a64(&zero_area[0..56]);
        zero_area[56..64].copy_from_slice(&sum.to_le_bytes());
        let r = decode_panel(&zero_area);
        if usize::try_from(1u64 << 40).is_err() {
            assert!(matches!(r, Err(SegioError::InvalidPanel(_))), "{r:?}");
        } else {
            let p = r.unwrap();
            assert_eq!((p.nrows, p.ncols), (1usize << 40, 0));
            assert!(p.data.is_empty());
        }
    }

    #[test]
    fn blob_roundtrip_and_defect_rejection() {
        for payload in [&b""[..], &b"x"[..], &[0u8, 255, 1, 2, 3, 128][..]] {
            let buf = encode_blob(payload);
            assert_eq!(buf.len() as u64, encoded_blob_len(payload.len()));
            assert_eq!(decode_blob(&buf).unwrap(), payload.to_vec());
        }

        let good = encode_blob(b"checkpoint body bytes");

        let mut bad_magic = good.clone();
        bad_magic[0] ^= 0xff;
        assert_eq!(decode_blob(&bad_magic), Err(SegioError::BadMagic));

        let mut bad_payload = good.clone();
        *bad_payload.last_mut().unwrap() ^= 0x01;
        assert!(matches!(decode_blob(&bad_payload), Err(SegioError::PayloadChecksum { .. })));

        let mut bad_header = good.clone();
        bad_header[40] ^= 0x01; // payload-length field
        assert!(matches!(decode_blob(&bad_header), Err(SegioError::HeaderChecksum { .. })));

        assert!(matches!(decode_blob(&good[..good.len() - 1]), Err(SegioError::Truncated { .. })));
        assert!(matches!(decode_blob(&good[..10]), Err(SegioError::Truncated { .. })));

        // Non-zero count fields with a re-sealed checksum are invalid —
        // a blob has no matrix shape to claim.
        let mut bad_counts = good.clone();
        bad_counts[16..24].copy_from_slice(&3u64.to_le_bytes());
        let sum = fnv1a64(&bad_counts[0..56]);
        bad_counts[56..64].copy_from_slice(&sum.to_le_bytes());
        assert!(matches!(decode_blob(&bad_counts), Err(SegioError::InvalidBlob(_))));
    }

    #[test]
    fn kind_confusion_is_a_typed_error_both_ways() {
        // A panel fed to the CSR decoder — and a CSR segment fed to the
        // panel decoder — must fail on the record kind, not misread bytes.
        let panel = encode_panel(&example_panel());
        assert_eq!(
            decode_segment(&panel),
            Err(SegioError::WrongKind { found: KIND_PANEL, expected: KIND_CSR })
        );
        let seg = encode_segment(&example_csr());
        assert_eq!(
            decode_panel(&seg),
            Err(SegioError::WrongKind { found: KIND_CSR, expected: KIND_PANEL })
        );
        let blob = encode_blob(b"opaque");
        assert_eq!(
            decode_segment(&blob),
            Err(SegioError::WrongKind { found: KIND_CHECK, expected: KIND_CSR })
        );
        assert_eq!(
            decode_blob(&seg),
            Err(SegioError::WrongKind { found: KIND_CSR, expected: KIND_CHECK })
        );
    }

    #[test]
    fn panel_file_roundtrip() {
        let dir = crate::testing::TempDir::new("segio-panel");
        let path = dir.path().join("panel.bin");
        let p = example_panel();
        let written = write_panel(&path, &p).unwrap();
        let mut scratch = Vec::new();
        let mut back = Dense::zeros(0, 0);
        let read = read_panel_into(&path, &mut scratch, &mut back).unwrap();
        assert_eq!(back, p);
        assert_eq!(written, read);
        assert!(matches!(
            read_panel_into(&dir.path().join("missing.bin"), &mut scratch, &mut back),
            Err(SegioError::Io(_))
        ));
        // A decode failure (not just a missing file) resets the scratch.
        let bytes = std::fs::read(&path).unwrap();
        std::fs::write(&path, &bytes[..bytes.len() - 2]).unwrap();
        assert!(matches!(
            read_panel_into(&path, &mut scratch, &mut back),
            Err(SegioError::Truncated { .. })
        ));
        assert_eq!((back.nrows, back.data.len()), (0, 0), "decode error resets the scratch panel");
    }

    #[test]
    fn golden_packed_encoding_is_byte_stable() {
        // Golden vector computed independently (Python struct/FNV-1a port
        // of the packed spec) — pins KIND_CSR_PACKED the same way the raw
        // golden vector pins KIND_CSR. For the example matrix the zigzag
        // codes are [0, 4, 2], so w = 3 and the single word is
        // 0 | 4<<3 | 2<<6 = 160.
        let want: [u8; 116] = [
            65, 73, 82, 69, 83, 83, 69, 71, 1, 0, 0, 0, 3, 0, 0, 0, 2, 0, 0, 0, 0, 0, 0, 0, 3, 0,
            0, 0, 0, 0, 0, 0, 3, 0, 0, 0, 0, 0, 0, 0, 52, 0, 0, 0, 0, 0, 0, 0, 22, 14, 37, 194,
            223, 101, 4, 181, 8, 209, 91, 116, 160, 217, 46, 0, 0, 0, 0, 0, 0, 0, 0, 0, 2, 0, 0,
            0, 0, 0, 0, 0, 3, 0, 0, 0, 0, 0, 0, 0, 3, 0, 0, 0, 0, 0, 0, 0, 160, 0, 0, 0, 0, 0, 0,
            0, 0, 0, 128, 63, 0, 0, 0, 64, 0, 0, 64, 64,
        ];
        let m = example_csr();
        let got = encode_segment_packed(&m);
        assert_eq!(got, want.to_vec());
        assert_eq!(got.len() as u64, encoded_packed_len(&m));
        assert_eq!(decode_segment(&got).unwrap(), m);
    }

    #[test]
    fn packed_roundtrips_across_shapes() {
        // Every shape class the packer branches on: empty matrices, empty
        // rows, single-row segments, single-column (w = 0) segments, and
        // extreme columns exercising the full 33-bit delta width.
        let cases: Vec<Csr> = vec![
            Csr::empty(0, 0),
            Csr::empty(3, 4),
            example_csr(),
            // Single row spanning the full u32 column range: the 0 → MAX
            // delta zigzags to 2^33 − 2, exercising the maximum width.
            Csr {
                nrows: 1,
                ncols: u32::MAX as usize + 1,
                rowptr: vec![0, 2],
                colidx: vec![0, u32::MAX],
                vals: vec![1.0, 2.0],
            },
            // Empty rows between occupied ones; per-row delta resets.
            Csr {
                nrows: 4,
                ncols: 100,
                rowptr: vec![0, 2, 2, 2, 5],
                colidx: vec![7, 99, 0, 50, 51],
                vals: vec![1.0, 2.0, 3.0, 4.0, 5.0],
            },
            // Single column everywhere: every code is 0, so w = 0 and the
            // packed colidx section is just the width word.
            Csr {
                nrows: 3,
                ncols: 1,
                rowptr: vec![0, 1, 2, 3],
                colidx: vec![0, 0, 0],
                vals: vec![1.0, 2.0, 3.0],
            },
        ];
        for m in cases {
            m.validate().expect("test case must be a valid CSR");
            let buf = encode_segment_packed(&m);
            assert_eq!(buf.len() as u64, encoded_packed_len(&m), "size predictor is exact");
            assert_eq!(decode_segment(&buf).unwrap(), m, "packed decode == original");
            // And the raw path agrees, entry for entry.
            assert_eq!(decode_segment(&encode_segment(&m)).unwrap(), m);
        }
    }

    #[test]
    fn auto_encoding_picks_the_smaller_file() {
        // Dense-ish local columns pack well below 32 bits per entry...
        let mut coo = Coo::new(64, 64);
        for r in 0..64 {
            for c in 0..8 {
                coo.push(r, c, (r + c) as f32 + 0.5);
            }
        }
        let local = coo.to_csr();
        assert!(encoded_packed_len(&local) < encoded_len(local.nrows, local.nnz()));
        let (buf, kind) = encode_segment_with(&local, SegEncoding::Auto);
        assert_eq!(kind, KIND_CSR_PACKED);
        assert_eq!(buf, encode_segment_packed(&local));

        // ...while an empty matrix gains nothing (packed adds the width
        // word), so Auto stays raw.
        let empty = Csr::empty(4, 4);
        assert!(encoded_packed_len(&empty) > encoded_len(4, 0));
        let (buf, kind) = encode_segment_with(&empty, SegEncoding::Auto);
        assert_eq!(kind, KIND_CSR);
        assert_eq!(buf, encode_segment(&empty));
    }

    #[test]
    fn seg_encoding_parses_and_displays() {
        for (s, e) in
            [("raw", SegEncoding::Raw), ("packed", SegEncoding::Packed), ("auto", SegEncoding::Auto)]
        {
            assert_eq!(s.parse::<SegEncoding>().unwrap(), e);
            assert_eq!(e.to_string(), s);
        }
        let err = "zstd".parse::<SegEncoding>().unwrap_err();
        assert!(err.contains("zstd") && err.contains("raw, packed, or auto"), "{err}");
        assert_eq!(SegEncoding::for_kind(KIND_CSR), Some(SegEncoding::Raw));
        assert_eq!(SegEncoding::for_kind(KIND_CSR_PACKED), Some(SegEncoding::Packed));
        assert_eq!(SegEncoding::for_kind(KIND_PANEL), None);
    }

    #[test]
    fn packed_rejects_crafted_defects_with_typed_errors() {
        let m = example_csr();
        let good = encode_segment_packed(&m);
        let reseal = |buf: &mut Vec<u8>| {
            let psum = fnv1a64(&buf[HEADER_BYTES..]);
            buf[48..56].copy_from_slice(&psum.to_le_bytes());
            let sum = fnv1a64(&buf[0..56]);
            buf[56..64].copy_from_slice(&sum.to_le_bytes());
        };
        let width_off = HEADER_BYTES + 3 * 8; // width byte follows rowptr

        // Ordinary corruption fails the checksums, same as raw records.
        let mut flipped = good.clone();
        *flipped.last_mut().unwrap() ^= 0x01;
        assert!(matches!(decode_segment(&flipped), Err(SegioError::PayloadChecksum { .. })));
        assert!(matches!(decode_segment(&good[..good.len() - 1]), Err(SegioError::Truncated { .. })));

        // Out-of-range width, fully re-sealed: typed rejection.
        let mut wide = good.clone();
        wide[width_off] = (PACKED_WIDTH_MAX + 1) as u8;
        reseal(&mut wide);
        match decode_segment(&wide) {
            Err(SegioError::InvalidCsr(msg)) => assert!(msg.contains("bit width"), "{msg}"),
            other => panic!("expected InvalidCsr for oversized width, got {other:?}"),
        }

        // Dirty pad bytes, re-sealed.
        let mut dirty = good.clone();
        dirty[width_off + 3] = 0x5a;
        reseal(&mut dirty);
        match decode_segment(&dirty) {
            Err(SegioError::InvalidCsr(msg)) => assert!(msg.contains("pad"), "{msg}"),
            other => panic!("expected InvalidCsr for dirty pad, got {other:?}"),
        }

        // A width inconsistent with the payload length, re-sealed: the
        // exact-length check fires before any bit is read.
        let mut short_w = good.clone();
        short_w[width_off] = 1; // claims 1-bit codes → fewer words than present
        reseal(&mut short_w);
        match decode_segment(&short_w) {
            Err(SegioError::InvalidCsr(msg)) => {
                assert!(msg.contains("inconsistent with packed bit width"), "{msg}")
            }
            other => panic!("expected InvalidCsr for width/length mismatch, got {other:?}"),
        }

        // Codes whose deltas walk below zero: flip the first code (zigzag
        // 0 → 1, i.e. delta −1 from column 0), re-sealed.
        let mut neg = good.clone();
        neg[width_off + 8] = 1 | (4 << 3) | (2 << 6);
        reseal(&mut neg);
        match decode_segment(&neg) {
            Err(SegioError::InvalidCsr(msg)) => assert!(msg.contains("u32 range"), "{msg}"),
            other => panic!("expected InvalidCsr for out-of-range delta, got {other:?}"),
        }

        // Truncating the header-advertised payload is Truncated, and a
        // packed record fed to the panel/blob decoders is WrongKind.
        assert_eq!(
            decode_panel(&good),
            Err(SegioError::WrongKind { found: KIND_CSR_PACKED, expected: KIND_PANEL })
        );
        assert_eq!(
            decode_blob(&good),
            Err(SegioError::WrongKind { found: KIND_CSR_PACKED, expected: KIND_CHECK })
        );
    }

    #[test]
    fn segment_ref_matches_the_copying_decoder() {
        let m = example_csr();
        let buf = encode_segment(&m);
        let r = decode_segment_ref(&buf).unwrap();
        assert_eq!((r.nrows, r.ncols, r.nnz()), (m.nrows, m.ncols, m.nnz()));
        let mut rowptr = Vec::new();
        r.fill_rowptr(&mut rowptr);
        assert_eq!(rowptr, m.rowptr);
        for i in 0..=m.nrows {
            assert_eq!(r.rowptr(i), m.rowptr[i]);
        }
        // Vec<u8> payloads start at offset 64 of an 8-aligned-at-best
        // allocation, so the borrow may legitimately fail on alignment;
        // when it succeeds it must be exact.
        if let Some(cols) = r.colidx_u32() {
            assert_eq!(cols, &m.colidx[..]);
        }
        if let Some(vals) = r.vals_f32() {
            assert_eq!(vals, &m.vals[..]);
        }

        // Same defect surface as the copying decoder.
        assert!(matches!(decode_segment_ref(&buf[..20]), Err(SegioError::Truncated { .. })));
        let mut bad = buf.clone();
        *bad.last_mut().unwrap() ^= 1;
        assert!(matches!(decode_segment_ref(&bad), Err(SegioError::PayloadChecksum { .. })));
        let invalid =
            Csr { nrows: 2, ncols: 2, rowptr: vec![0, 2, 1], colidx: vec![0], vals: vec![1.0] };
        let enc = {
            // Hand-build a record with nnz = 1 and a non-monotone rowptr so
            // the length checks pass and only the invariant walk can catch it.
            let mut payload = Vec::new();
            for p in [0u64, 2, 1] {
                put_u64(&mut payload, p);
            }
            put_u32(&mut payload, 0);
            put_u32(&mut payload, 1.0f32.to_bits());
            seal_header(KIND_CSR, invalid.nrows, invalid.ncols, 1, payload)
        };
        assert!(matches!(decode_segment_ref(&enc), Err(SegioError::InvalidCsr(_))));

        // Packed records are copy-decode only: the zero-copy reader names
        // the kind rather than guessing at the bitstream.
        let packed = encode_segment_packed(&m);
        match decode_segment_ref(&packed) {
            Err(SegioError::WrongKind { found, expected }) => {
                assert_eq!((found, expected), (KIND_CSR_PACKED, KIND_CSR));
            }
            other => panic!("expected WrongKind for a packed record, got {other:?}"),
        }
    }

    #[test]
    fn panel_ref_matches_the_copying_decoder() {
        let p = example_panel();
        let buf = encode_panel(&p);
        let r = decode_panel_ref(&buf).unwrap();
        assert_eq!((r.nrows, r.ncols), (p.nrows, p.ncols));
        let mut out = vec![0.0f32; p.data.len()];
        r.fill_into(&mut out);
        assert_eq!(out, p.data);
        if let Some(data) = r.data_f32() {
            assert_eq!(data, &p.data[..]);
        }
        assert!(matches!(decode_panel_ref(&buf[..30]), Err(SegioError::Truncated { .. })));
        let seg = encode_segment(&example_csr());
        assert_eq!(
            decode_panel_ref(&seg).err(),
            Some(SegioError::WrongKind { found: KIND_CSR, expected: KIND_PANEL })
        );
    }

    #[test]
    fn payload_copy_counter_counts_copy_decodes() {
        let m = example_csr();
        let raw = encode_segment(&m);
        let before = payload_copy_count();
        for _ in 0..5 {
            let _ = decode_segment(&raw).unwrap();
        }
        assert!(payload_copy_count() >= before + 5, "copy decodes are counted");
        // The borrowed decoder's zero-copy claim is asserted in isolation
        // by the warm-mmap gate in rust/tests/alloc_free.rs — the counter
        // is process-global, so an exact no-movement check here would race
        // with sibling tests decoding concurrently.
    }
}
