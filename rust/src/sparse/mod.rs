//! Sparse matrix substrate: formats, conversions, reference kernels.
//!
//! This is the foundation the paper's system sits on: CSR/CSC/COO containers
//! (paper §II-B, Fig. 2), the Â = D^-1/2 (A+I) D^-1/2 normalization
//! (Eqs. 1-2), a Gustavson SpGEMM that serves as the CPU correctness oracle
//! for everything the accelerator path computes, and block-sparse (BSR)
//! extraction feeding the RoBW-aligned tile pipeline.
//!
//! Index width: `u32` column/row ids (all paper datasets fit; 214 M < 2^32)
//! with `usize` offset arrays, mirroring common sparse libraries.

pub mod block;
pub mod coo;
pub mod csc;
pub mod csr;
pub mod norm;
pub mod reorder;
pub mod segio;
pub mod spgemm;
pub mod spmm;

pub use block::{Bsr, BsrRowBlock};
pub use coo::Coo;
pub use csc::Csc;
pub use csr::{Csr, SegView};

/// Bytes per non-zero value (f32 payload).
pub const VAL_BYTES: u64 = 4;
/// Bytes per index entry (u32).
pub const IDX_BYTES: u64 = 4;
/// Bytes per offset-array entry. The paper's C++ implementation uses int
/// row pointers; we account 8 bytes (usize) to be conservative.
pub const PTR_BYTES: u64 = 8;
