//! Compressed sparse row (CSR) matrix (paper Fig. 2, matrix A's format).

use super::{Coo, Csc, IDX_BYTES, PTR_BYTES, VAL_BYTES};

/// CSR matrix: `rowptr[i]..rowptr[i+1]` indexes the non-zeros of row `i`.
///
/// This is the format of the paper's matrix A — the operand RoBW
/// partitioning slices and the accelerator path regrids into BSR tiles.
///
/// # Examples
///
/// Build a small matrix through [`Coo`] (the interchange format every
/// generator emits) and inspect it:
///
/// ```
/// use aires::sparse::{Coo, Csr};
///
/// // [[1, 0, 2],
/// //  [0, 3, 0]]
/// let mut coo = Coo::new(2, 3);
/// coo.push(0, 0, 1.0);
/// coo.push(0, 2, 2.0);
/// coo.push(1, 1, 3.0);
/// let m: Csr = coo.to_csr();
///
/// assert_eq!(m.nnz(), 3);
/// assert_eq!(m.row_nnz(0), 2);
/// assert_eq!(m.row(1).collect::<Vec<_>>(), vec![(1, 3.0)]);
/// assert!(m.validate().is_ok());
/// ```
#[derive(Debug, Clone, PartialEq)]
pub struct Csr {
    /// Row count.
    pub nrows: usize,
    /// Column count.
    pub ncols: usize,
    /// len nrows + 1, monotonically non-decreasing, last entry == nnz.
    pub rowptr: Vec<usize>,
    /// len nnz; column index per non-zero, sorted within each row.
    pub colidx: Vec<u32>,
    /// len nnz; value per non-zero.
    pub vals: Vec<f32>,
}

/// Borrowed view of a CSR segment's three sections — the operand type the
/// SpMM kernels actually consume. An owned [`Csr`] yields one via
/// [`Csr::view`]; the zero-copy mapped segment path yields one whose
/// colidx/vals borrow the page cache directly, so the kernels are written
/// once against `SegView` and serve both without copies or dispatch.
#[derive(Debug, Clone, Copy)]
pub struct SegView<'a> {
    /// Row count.
    pub nrows: usize,
    /// Column count.
    pub ncols: usize,
    /// len nrows + 1, monotonically non-decreasing, last entry == nnz.
    pub rowptr: &'a [usize],
    /// len nnz; column index per non-zero, sorted within each row.
    pub colidx: &'a [u32],
    /// len nnz; value per non-zero.
    pub vals: &'a [f32],
}

impl SegView<'_> {
    /// Stored non-zero count.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.colidx.len()
    }
}

impl Csr {
    /// Empty matrix with the given shape.
    pub fn empty(nrows: usize, ncols: usize) -> Self {
        Csr { nrows, ncols, rowptr: vec![0; nrows + 1], colidx: Vec::new(), vals: Vec::new() }
    }

    /// Borrow this matrix's sections as a [`SegView`] (the kernels' common
    /// operand type).
    #[inline]
    pub fn view(&self) -> SegView<'_> {
        SegView {
            nrows: self.nrows,
            ncols: self.ncols,
            rowptr: &self.rowptr,
            colidx: &self.colidx,
            vals: &self.vals,
        }
    }

    /// Build from parts, validating the CSR invariants.
    pub fn new(
        nrows: usize,
        ncols: usize,
        rowptr: Vec<usize>,
        colidx: Vec<u32>,
        vals: Vec<f32>,
    ) -> Result<Self, String> {
        let m = Csr { nrows, ncols, rowptr, colidx, vals };
        m.validate()?;
        Ok(m)
    }

    /// Check structural invariants; used by tests and the property suite.
    pub fn validate(&self) -> Result<(), String> {
        if self.rowptr.len() != self.nrows + 1 {
            return Err(format!("rowptr len {} != nrows+1 {}", self.rowptr.len(), self.nrows + 1));
        }
        if self.rowptr[0] != 0 {
            return Err("rowptr[0] != 0".into());
        }
        if *self.rowptr.last().unwrap() != self.colidx.len() {
            return Err("rowptr[-1] != nnz".into());
        }
        if self.colidx.len() != self.vals.len() {
            return Err("colidx/vals length mismatch".into());
        }
        for w in self.rowptr.windows(2) {
            if w[1] < w[0] {
                return Err("rowptr not monotone".into());
            }
        }
        for r in 0..self.nrows {
            let row = &self.colidx[self.rowptr[r]..self.rowptr[r + 1]];
            for w in row.windows(2) {
                if w[1] <= w[0] {
                    return Err(format!("row {r} columns not strictly sorted"));
                }
            }
            if let Some(&c) = row.last() {
                if c as usize >= self.ncols {
                    return Err(format!("row {r} column {c} out of bounds"));
                }
            }
        }
        Ok(())
    }

    /// Stored non-zero count.
    #[inline]
    pub fn nnz(&self) -> usize {
        self.colidx.len()
    }

    /// Non-zeros in row `r`.
    #[inline]
    pub fn row_nnz(&self, r: usize) -> usize {
        self.rowptr[r + 1] - self.rowptr[r]
    }

    /// (column, value) iterator over row `r`.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (u32, f32)> + '_ {
        let lo = self.rowptr[r];
        let hi = self.rowptr[r + 1];
        self.colidx[lo..hi].iter().copied().zip(self.vals[lo..hi].iter().copied())
    }

    /// Sparsity as a percentage of zero elements (paper's s_A notation).
    pub fn sparsity_pct(&self) -> f64 {
        let total = self.nrows as f64 * self.ncols as f64;
        if total == 0.0 {
            return 100.0;
        }
        100.0 * (1.0 - self.nnz() as f64 / total)
    }

    /// In-memory footprint in bytes (values + column ids + row pointers) —
    /// the quantity the paper's Table II "Memory Req." accounts per operand.
    pub fn size_bytes(&self) -> u64 {
        self.nnz() as u64 * (VAL_BYTES + IDX_BYTES) + (self.nrows as u64 + 1) * PTR_BYTES
    }

    /// Transpose into CSC (same buffers reinterpreted: CSC of A == CSR of Aᵀ).
    pub fn to_csc(&self) -> Csc {
        // Counting sort by column.
        let mut colptr = vec![0usize; self.ncols + 1];
        for &c in &self.colidx {
            colptr[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            colptr[i + 1] += colptr[i];
        }
        let mut rowidx = vec![0u32; self.nnz()];
        let mut vals = vec![0f32; self.nnz()];
        let mut next = colptr.clone();
        for r in 0..self.nrows {
            for (c, v) in self.row(r) {
                let dst = next[c as usize];
                rowidx[dst] = r as u32;
                vals[dst] = v;
                next[c as usize] += 1;
            }
        }
        Csc { nrows: self.nrows, ncols: self.ncols, colptr, rowidx, vals }
    }

    /// Back to COO triplets (row-major order — `to_csr` is the exact
    /// inverse, making Coo↔Csr a lossless round trip).
    pub fn to_coo(&self) -> Coo {
        let mut coo = Coo::new(self.nrows, self.ncols);
        for r in 0..self.nrows {
            for (c, v) in self.row(r) {
                coo.push(r as u32, c, v);
            }
        }
        coo
    }

    /// Dense row-major materialization (tests / small subgraphs only).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.nrows * self.ncols];
        for r in 0..self.nrows {
            for (c, v) in self.row(r) {
                out[r * self.ncols + c as usize] = v;
            }
        }
        out
    }

    /// Slice rows `[lo, hi)` into a new CSR (used by partitioners).
    pub fn slice_rows(&self, lo: usize, hi: usize) -> Csr {
        assert!(lo <= hi && hi <= self.nrows);
        let base = self.rowptr[lo];
        let end = self.rowptr[hi];
        Csr {
            nrows: hi - lo,
            ncols: self.ncols,
            rowptr: self.rowptr[lo..=hi].iter().map(|p| p - base).collect(),
            colidx: self.colidx[base..end].to_vec(),
            vals: self.vals[base..end].to_vec(),
        }
    }

    /// Slice rows `[lo, hi)` into caller-owned scratch: `out`'s sections
    /// are cleared and refilled in place, so a slice whose sections fit
    /// the scratch capacity performs zero heap allocations — the
    /// in-memory-staging counterpart of `segio::decode_segment_into`.
    pub fn slice_rows_into(&self, lo: usize, hi: usize, out: &mut Csr) {
        assert!(lo <= hi && hi <= self.nrows);
        let base = self.rowptr[lo];
        let end = self.rowptr[hi];
        out.nrows = hi - lo;
        out.ncols = self.ncols;
        out.rowptr.clear();
        out.rowptr.reserve(hi - lo + 1);
        out.rowptr.extend(self.rowptr[lo..=hi].iter().map(|p| p - base));
        out.colidx.clear();
        out.colidx.extend_from_slice(&self.colidx[base..end]);
        out.vals.clear();
        out.vals.extend_from_slice(&self.vals[base..end]);
    }

    /// Vertically concatenate row slices (inverse of `slice_rows`; the
    /// "merge" operation the naive partitioner is forced to perform).
    /// Output sections are pre-sized from the parts' totals, so assembly
    /// never regrows mid-concatenation.
    pub fn vstack(parts: &[Csr]) -> Result<Csr, String> {
        if parts.is_empty() {
            return Err("vstack of nothing".into());
        }
        let ncols = parts[0].ncols;
        let total_rows: usize = parts.iter().map(|p| p.nrows).sum();
        let total_nnz: usize = parts.iter().map(|p| p.nnz()).sum();
        let mut rowptr = Vec::with_capacity(total_rows + 1);
        rowptr.push(0usize);
        let mut colidx = Vec::with_capacity(total_nnz);
        let mut vals = Vec::with_capacity(total_nnz);
        let mut nrows = 0;
        for p in parts {
            if p.ncols != ncols {
                return Err("vstack ncols mismatch".into());
            }
            let base = *rowptr.last().unwrap();
            rowptr.extend(p.rowptr[1..].iter().map(|q| q + base));
            colidx.extend_from_slice(&p.colidx);
            vals.extend_from_slice(&p.vals);
            nrows += p.nrows;
        }
        Csr::new(nrows, ncols, rowptr, colidx, vals)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    pub fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, rng.normal() as f32);
                }
            }
        }
        coo.to_csr()
    }

    #[test]
    fn validate_catches_bad_rowptr() {
        let m = Csr { nrows: 2, ncols: 2, rowptr: vec![0, 2, 1], colidx: vec![0], vals: vec![1.0] };
        assert!(m.validate().is_err());
    }

    #[test]
    fn validate_catches_unsorted_columns() {
        let m = Csr {
            nrows: 1,
            ncols: 4,
            rowptr: vec![0, 2],
            colidx: vec![2, 1],
            vals: vec![1.0, 2.0],
        };
        assert!(m.validate().is_err());
    }

    #[test]
    fn csc_roundtrip_preserves_entries() {
        let mut rng = Pcg::seed(1);
        let a = random_csr(&mut rng, 17, 13, 0.2);
        let csc = a.to_csc();
        let back = csc.to_csr();
        assert_eq!(a, back);
    }

    #[test]
    fn slice_then_vstack_is_identity() {
        let mut rng = Pcg::seed(2);
        let a = random_csr(&mut rng, 20, 9, 0.3);
        let parts: Vec<Csr> =
            vec![a.slice_rows(0, 7), a.slice_rows(7, 7), a.slice_rows(7, 15), a.slice_rows(15, 20)];
        let merged = Csr::vstack(&parts).unwrap();
        assert_eq!(a, merged);
    }

    #[test]
    fn slice_rows_into_matches_slice_rows_and_reuses_scratch() {
        let mut rng = Pcg::seed(3);
        let a = random_csr(&mut rng, 30, 11, 0.3);
        let mut scratch = Csr::empty(0, 0);
        for (lo, hi) in [(0usize, 12usize), (12, 12), (5, 30), (0, 30)] {
            a.slice_rows_into(lo, hi, &mut scratch);
            assert_eq!(scratch, a.slice_rows(lo, hi), "rows [{lo}, {hi})");
        }
        // A stale, larger previous slice must be fully overwritten.
        a.slice_rows_into(0, 30, &mut scratch);
        a.slice_rows_into(10, 13, &mut scratch);
        assert_eq!(scratch, a.slice_rows(10, 13));
        scratch.validate().unwrap();
    }

    #[test]
    fn sparsity_pct() {
        let m = Csr::new(2, 2, vec![0, 1, 1], vec![0], vec![1.0]).unwrap();
        assert!((m.sparsity_pct() - 75.0).abs() < 1e-9);
    }

    #[test]
    fn size_bytes_accounting() {
        let m = Csr::new(2, 2, vec![0, 1, 1], vec![0], vec![1.0]).unwrap();
        assert_eq!(m.size_bytes(), 1 * (4 + 4) + 3 * 8);
    }
}
