//! SpMM: CSR × dense — the aggregation step (Eq. 1) when the feature panel
//! is materialized densely, and the CPU oracle for the `bsr_spmm` artifact.
//!
//! The inner loops are a **lane-blocked microkernel** (the GE-SpMM /
//! Accel-GCN feature-dimension blocking, arXiv:2007.03179 /
//! arXiv:2308.11825, at CPU scale): the feature dimension is walked in
//! fixed [`SPMM_LANES`]-wide blocks with a scalar-width tail, each block's
//! partial sums living in a register-resident accumulator array across the
//! whole sparse row, and row slicing hoisted out of the nnz loop. Each
//! output element still receives exactly the serial sequence of
//! `acc += a_ik * h_kj` operations in `k` (row) order, so the blocked
//! kernels are **bit-identical** to the scalar loops they replaced
//! (enforced against an in-test scalar oracle in
//! `rust/tests/differential.rs`).
//!
//! `spmm_par` / `spmm_transpose_par` are the row-range parallel variants on
//! [`crate::runtime::pool::Pool`]: fixed contiguous output-row partitions,
//! one writer per row, serial per-row arithmetic order — byte-identical to
//! the serial oracles at every thread count. `spmm_into` / `spmm_par_into`
//! write into a caller-owned destination (the per-layer aggregation panel
//! of the `gcn::pipeline` streaming engine), eliminating the per-segment
//! partial allocation the streaming hot loop used to pay.
//!
//! Since storage engine v2 the kernels are written against borrowed
//! operands: [`SegView`] for the sparse side (an owned [`Csr`] or a
//! zero-copy mapped segment) and the [`RowSrc`] trait for the dense side
//! (a resident [`Dense`] or a mapped panel-chunk set). The generics
//! monomorphize — no dynamic dispatch in the nnz loop — and every `Csr` /
//! `Dense` entry point below is a thin delegation, so the arithmetic
//! order (and therefore bit-identity with the serial oracle) is unchanged.

use crate::runtime::pool::Pool;

use super::{Csr, SegView};

/// Feature-dimension block width of the SpMM microkernel. Eight f32 lanes
/// fill two SSE / one AVX register; the accumulator array is a fixed-size
/// stack array the compiler keeps in registers across the sparse row.
pub const SPMM_LANES: usize = 8;

/// Dense row-major matrix, the interchange type between the sparse substrate
/// and the PJRT runtime (which consumes flat f32 buffers).
#[derive(Debug, Clone, PartialEq)]
pub struct Dense {
    /// Row count.
    pub nrows: usize,
    /// Column count.
    pub ncols: usize,
    /// Row-major values, `data[r * ncols + c]`.
    pub data: Vec<f32>,
}

impl Dense {
    /// All-zero matrix of the given shape.
    pub fn zeros(nrows: usize, ncols: usize) -> Self {
        Dense { nrows, ncols, data: vec![0f32; nrows * ncols] }
    }

    /// Wrap a row-major buffer (must be exactly `nrows * ncols` long).
    pub fn from_vec(nrows: usize, ncols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), nrows * ncols);
        Dense { nrows, ncols, data }
    }

    /// Element at `(r, c)`.
    #[inline]
    pub fn at(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.ncols + c]
    }

    /// Mutable element at `(r, c)`.
    #[inline]
    pub fn at_mut(&mut self, r: usize, c: usize) -> &mut f32 {
        &mut self.data[r * self.ncols + c]
    }

    /// Row `r` as a slice.
    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.ncols..(r + 1) * self.ncols]
    }

    /// Sparsify into CSR, dropping |v| <= eps (the paper's output is
    /// CSR C; the accelerator path produces dense row blocks that are
    /// re-compressed before leaving the device working set). A counting
    /// pass sizes the index/value sections exactly up front, so the Phase
    /// III packaging loop never regrows them from empty.
    pub fn to_csr(&self, eps: f32) -> super::Csr {
        let nnz = self.data.iter().filter(|v| v.abs() > eps).count();
        let mut rowptr = Vec::with_capacity(self.nrows + 1);
        rowptr.push(0usize);
        let mut colidx = Vec::with_capacity(nnz);
        let mut vals = Vec::with_capacity(nnz);
        for r in 0..self.nrows {
            for (c, &v) in self.row(r).iter().enumerate() {
                if v.abs() > eps {
                    colidx.push(c as u32);
                    vals.push(v);
                }
            }
            rowptr.push(colidx.len());
        }
        super::Csr { nrows: self.nrows, ncols: self.ncols, rowptr, colidx, vals }
    }

    /// Max absolute elementwise difference (test helper).
    pub fn max_abs_diff(&self, other: &Dense) -> f32 {
        assert_eq!((self.nrows, self.ncols), (other.nrows, other.ncols));
        self.data
            .iter()
            .zip(other.data.iter())
            .map(|(a, b)| (a - b).abs())
            .fold(0f32, f32::max)
    }
}

/// The SpMM kernels' dense operand: anything that serves feature row `r`
/// as one contiguous `&[f32]`. [`Dense`] serves from its resident buffer;
/// the mapped panel-chunk reader (`runtime::segstore`) serves rows
/// straight out of page-cache-backed mappings. The kernels are generic
/// (monomorphized) over this trait, so neither side pays dispatch in the
/// nnz loop.
pub trait RowSrc {
    /// Row count.
    fn nrows(&self) -> usize;
    /// Feature width (elements per row).
    fn ncols(&self) -> usize;
    /// Row `r` as a contiguous slice of length [`RowSrc::ncols`].
    fn row(&self, r: usize) -> &[f32];
}

impl RowSrc for Dense {
    #[inline]
    fn nrows(&self) -> usize {
        self.nrows
    }

    #[inline]
    fn ncols(&self) -> usize {
        self.ncols
    }

    #[inline]
    fn row(&self, r: usize) -> &[f32] {
        Dense::row(self, r)
    }
}

/// Lane-blocked microkernel for one output row: `orow = A[i, :] · H`,
/// overwriting `orow` entirely (rows with no stored entries become zero).
///
/// The feature dimension is walked in [`SPMM_LANES`]-wide blocks with a
/// narrower tail; each block keeps its partial sums in a fixed stack
/// accumulator across the whole sparse row, loading/storing the output
/// once per block instead of once per non-zero. Row slicing (`rowptr`
/// lookup, section slices) is hoisted out of the nnz loop. Per output
/// element the f32 operation sequence is exactly the scalar kernel's
/// (`acc += a_ik * h_kj` in stored-`k` order), so results are
/// bit-identical to the pre-blocking loops.
#[inline]
fn spmm_row_into<S: RowSrc + ?Sized>(a: SegView<'_>, h: &S, i: usize, orow: &mut [f32]) {
    let f = h.ncols();
    let lo = a.rowptr[i];
    let hi = a.rowptr[i + 1];
    let cols = &a.colidx[lo..hi];
    let vals = &a.vals[lo..hi];
    let mut j = 0usize;
    while j + SPMM_LANES <= f {
        let mut acc = [0f32; SPMM_LANES];
        for (&k, &av) in cols.iter().zip(vals.iter()) {
            let hblk = &h.row(k as usize)[j..j + SPMM_LANES];
            for l in 0..SPMM_LANES {
                acc[l] += av * hblk[l];
            }
        }
        orow[j..j + SPMM_LANES].copy_from_slice(&acc);
        j += SPMM_LANES;
    }
    if j < f {
        // Scalar-width tail: same accumulator discipline, partial block.
        let t = f - j;
        let mut acc = [0f32; SPMM_LANES];
        for (&k, &av) in cols.iter().zip(vals.iter()) {
            let hblk = &h.row(k as usize)[j..j + t];
            for (al, &hv) in acc[..t].iter_mut().zip(hblk.iter()) {
                *al += av * hv;
            }
        }
        orow[j..f].copy_from_slice(&acc[..t]);
    }
}

/// Lane-blocked `orow += av * hrow` (the transpose kernel's scatter step):
/// [`SPMM_LANES`]-wide unrolled blocks with a scalar tail. Element order
/// within the row is ascending either way, so this is bit-identical to the
/// scalar zip loop it replaced.
#[inline]
fn axpy_lanes(orow: &mut [f32], hrow: &[f32], av: f32) {
    let mut ob = orow.chunks_exact_mut(SPMM_LANES);
    let mut hb = hrow.chunks_exact(SPMM_LANES);
    for (o, hc) in ob.by_ref().zip(hb.by_ref()) {
        for l in 0..SPMM_LANES {
            o[l] += av * hc[l];
        }
    }
    for (o, &hv) in ob.into_remainder().iter_mut().zip(hb.remainder().iter()) {
        *o += av * hv;
    }
}

/// out = A · H, A in CSR, H dense. Row-major streaming: one pass over nnz
/// through the lane-blocked microkernel.
pub fn spmm(a: &Csr, h: &Dense) -> Dense {
    let mut out = Dense::zeros(a.nrows, h.ncols);
    spmm_into(a, h, &mut out.data);
    out
}

/// [`spmm`] into a caller-owned destination: `out` must hold exactly
/// `a.nrows * h.ncols` row-major elements and is **overwritten** (no
/// pre-zeroing needed). This is how the streaming forward pass computes
/// each segment's partial directly into its row range of the pass-wide
/// aggregation panel instead of allocating a fresh partial per segment.
pub fn spmm_into(a: &Csr, h: &Dense, out: &mut [f32]) {
    spmm_view_into(a.view(), h, out);
}

/// [`spmm_into`] over borrowed operands: A as a [`SegView`], H as any
/// [`RowSrc`] — the form the zero-copy mapped path calls, with the mapped
/// segment's sections and panel-chunk rows served in place.
pub fn spmm_view_into<S: RowSrc + ?Sized>(a: SegView<'_>, h: &S, out: &mut [f32]) {
    assert_eq!(a.ncols, h.nrows(), "inner dimension mismatch");
    let f = h.ncols();
    assert_eq!(out.len(), a.nrows * f, "destination shape mismatch");
    for i in 0..a.nrows {
        spmm_row_into(a, h, i, &mut out[i * f..(i + 1) * f]);
    }
}

/// Row-parallel `out = A · H`: output rows are split into one contiguous
/// chunk per pool worker; each worker runs the serial lane-blocked kernel
/// over its rows. Byte-identical to [`spmm`] (same per-row accumulation
/// order).
pub fn spmm_par(a: &Csr, h: &Dense, pool: &Pool) -> Dense {
    let mut out = Dense::zeros(a.nrows, h.ncols);
    spmm_par_into(a, h, pool, &mut out.data);
    out
}

/// [`spmm_par`] into a caller-owned destination (see [`spmm_into`]).
pub fn spmm_par_into(a: &Csr, h: &Dense, pool: &Pool, out: &mut [f32]) {
    spmm_view_par_into(a.view(), h, pool, out);
}

/// [`spmm_par_into`] over borrowed operands (see [`spmm_view_into`]): same
/// fixed row-range partitioning, so byte-identical to the serial form at
/// every thread count regardless of where the operands live.
pub fn spmm_view_par_into<S: RowSrc + Sync + ?Sized>(
    a: SegView<'_>,
    h: &S,
    pool: &Pool,
    out: &mut [f32],
) {
    assert_eq!(a.ncols, h.nrows(), "inner dimension mismatch");
    let f = h.ncols();
    assert_eq!(out.len(), a.nrows * f, "destination shape mismatch");
    pool.for_each_row_chunk(out, f, |range, chunk| {
        for (local, i) in range.clone().enumerate() {
            spmm_row_into(a, h, i, &mut chunk[local * f..(local + 1) * f]);
        }
    });
}

/// out = Aᵀ · H without materializing Aᵀ (scatter form) — backward pass of
/// aggregation for the training path.
pub fn spmm_transpose(a: &Csr, h: &Dense) -> Dense {
    assert_eq!(a.nrows, h.nrows, "inner dimension mismatch");
    let f = h.ncols;
    let mut out = Dense::zeros(a.ncols, f);
    for i in 0..a.nrows {
        let hrow = h.row(i);
        for (k, av) in a.row(i) {
            let orow = &mut out.data[k as usize * f..(k as usize + 1) * f];
            axpy_lanes(orow, hrow, av);
        }
    }
    out
}

/// Row-parallel `out = Aᵀ · H`. The serial form scatters (row i of A adds
/// into output row k for every stored (i, k)), which parallelizes only via
/// atomics — and atomics-ordered accumulation is non-deterministic. Instead
/// each worker owns a contiguous *output* row range and scans all of A,
/// keeping only the contributions that land in its range. Each output
/// element receives the same additions in the same (i, then colidx) order
/// as [`spmm_transpose`], so the result is byte-identical at every thread
/// count; the cost is one read pass over nnz(A) per worker — the
/// determinism-over-scatter tradeoff, acceptable because A is read-shared
/// and the pass is bandwidth-cheap next to the FLOP work it feeds. Uses
/// the static (one chunk per worker) split: every chunk scans all of A,
/// so oversubscribed chunks would multiply total work.
pub fn spmm_transpose_par(a: &Csr, h: &Dense, pool: &Pool) -> Dense {
    assert_eq!(a.nrows, h.nrows, "inner dimension mismatch");
    let f = h.ncols;
    let mut out = Dense::zeros(a.ncols, f);
    pool.for_each_row_chunk_static(&mut out.data, f, |range, chunk| {
        for i in 0..a.nrows {
            let hrow = h.row(i);
            for (k, av) in a.row(i) {
                let k = k as usize;
                if k < range.start || k >= range.end {
                    continue;
                }
                let local = k - range.start;
                axpy_lanes(&mut chunk[local * f..(local + 1) * f], hrow, av);
            }
        }
    });
    out
}

/// `out += Aᵀ · H` into a caller-owned accumulator — the segment-wise form
/// of [`spmm_transpose`] the streamed backward pass uses. `h` is the
/// segment's `a.nrows × f` row-major operand (a row range of the upstream
/// gradient panel) and `out` is the full `a.ncols × f` destination panel,
/// **accumulated into** (the caller zeroes it once per layer) — the
/// accumulate-vs-overwrite contrast with [`spmm_into`], because every
/// RoBW segment scatters into the same output rows.
///
/// Segment-wise accumulation is byte-identical to one whole-matrix
/// [`spmm_transpose`]: segments cover ascending row ranges and each
/// segment scans its rows ascending, so every output element receives its
/// `acc += a_ik * h_ij` additions in the same global row order either way.
pub fn spmm_transpose_into(a: &Csr, h: &[f32], f: usize, out: &mut [f32]) {
    spmm_transpose_view_into(a.view(), h, f, out);
}

/// [`spmm_transpose_into`] over a borrowed segment view — the form the
/// streamed backward pass calls when the segment arrives mapped.
pub fn spmm_transpose_view_into(a: SegView<'_>, h: &[f32], f: usize, out: &mut [f32]) {
    assert_eq!(h.len(), a.nrows * f, "operand shape mismatch");
    assert_eq!(out.len(), a.ncols * f, "destination shape mismatch");
    for i in 0..a.nrows {
        let hrow = &h[i * f..(i + 1) * f];
        let (lo, hi) = (a.rowptr[i], a.rowptr[i + 1]);
        for (&k, &av) in a.colidx[lo..hi].iter().zip(a.vals[lo..hi].iter()) {
            let k = k as usize;
            axpy_lanes(&mut out[k * f..(k + 1) * f], hrow, av);
        }
    }
}

/// Row-parallel [`spmm_transpose_into`]: same deterministic
/// owner-scans-all discipline as [`spmm_transpose_par`] (each worker owns
/// a contiguous destination row range and scans the whole segment), so the
/// accumulated result is byte-identical to the serial form at every thread
/// count.
pub fn spmm_transpose_par_into(a: &Csr, h: &[f32], f: usize, pool: &Pool, out: &mut [f32]) {
    spmm_transpose_view_par_into(a.view(), h, f, pool, out);
}

/// [`spmm_transpose_par_into`] over a borrowed segment view (same
/// owner-scans-all determinism discipline).
pub fn spmm_transpose_view_par_into(
    a: SegView<'_>,
    h: &[f32],
    f: usize,
    pool: &Pool,
    out: &mut [f32],
) {
    assert_eq!(h.len(), a.nrows * f, "operand shape mismatch");
    assert_eq!(out.len(), a.ncols * f, "destination shape mismatch");
    pool.for_each_row_chunk_static(out, f, |range, chunk| {
        for i in 0..a.nrows {
            let hrow = &h[i * f..(i + 1) * f];
            let (lo, hi) = (a.rowptr[i], a.rowptr[i + 1]);
            for (&k, &av) in a.colidx[lo..hi].iter().zip(a.vals[lo..hi].iter()) {
                let k = k as usize;
                if k < range.start || k >= range.end {
                    continue;
                }
                let local = k - range.start;
                axpy_lanes(&mut chunk[local * f..(local + 1) * f], hrow, av);
            }
        }
    });
}

/// Assemble the sparse output CSR C from per-segment dense results —
/// Phase III's final packaging (complete rows per RoBW segment make this
/// a pure concatenation, the very property the alignment buys). The
/// sections are pre-sized end to end: [`Dense::to_csr`] counts each
/// part's nnz before building it, and [`Csr::vstack`] sizes the final
/// arrays from the parts' totals, so packaging never regrows a vector.
pub fn assemble_csr_c(segments: &[(usize, Dense)], ncols: usize, eps: f32) -> super::Csr {
    let mut parts: Vec<super::Csr> = Vec::with_capacity(segments.len());
    let mut expected_row = 0usize;
    for (row_lo, d) in segments {
        assert_eq!(*row_lo, expected_row, "segments must be contiguous");
        expected_row += d.nrows;
        assert_eq!(d.ncols, ncols);
        parts.push(d.to_csr(eps));
    }
    super::Csr::vstack(&parts).expect("contiguous complete-row segments")
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;
    use crate::util::rng::Pcg;

    #[test]
    fn to_csr_roundtrip_dense() {
        let d = Dense::from_vec(2, 3, vec![1.0, 0.0, -2.0, 0.0, 0.0, 3.0]);
        let c = d.to_csr(0.0);
        c.validate().unwrap();
        assert_eq!(c.nnz(), 3);
        assert_eq!(c.to_dense(), d.data);
    }

    #[test]
    fn assemble_csr_c_equals_whole_product() {
        let mut rng = Pcg::seed(60);
        let a = crate::graphgen::kmer::generate(&mut rng, 120, 3.0);
        let h = Dense::from_vec(120, 6, (0..720).map(|_| rng.normal() as f32).collect());
        let whole = spmm(&a, &h).to_csr(0.0);
        let segs = crate::partition::robw::robw_partition(&a, 512);
        let parts: Vec<(usize, Dense)> = segs
            .iter()
            .map(|s| (s.row_lo, spmm(&crate::partition::robw::materialize(&a, s), &h)))
            .collect();
        let assembled = assemble_csr_c(&parts, 6, 0.0);
        assert_eq!(whole.to_dense(), assembled.to_dense());
    }

    fn random_csr(rng: &mut Pcg, nrows: usize, ncols: usize, density: f64) -> Csr {
        let mut coo = Coo::new(nrows, ncols);
        for r in 0..nrows {
            for c in 0..ncols {
                if rng.chance(density) {
                    coo.push(r as u32, c as u32, rng.normal() as f32);
                }
            }
        }
        coo.to_csr()
    }

    fn random_dense(rng: &mut Pcg, nrows: usize, ncols: usize) -> Dense {
        Dense::from_vec(
            nrows,
            ncols,
            (0..nrows * ncols).map(|_| rng.normal() as f32).collect(),
        )
    }

    fn dense_spmm(a: &Csr, h: &Dense) -> Dense {
        let ad = a.to_dense();
        let mut out = Dense::zeros(a.nrows, h.ncols);
        for i in 0..a.nrows {
            for k in 0..a.ncols {
                let av = ad[i * a.ncols + k];
                for j in 0..h.ncols {
                    *out.at_mut(i, j) += av * h.at(k, j);
                }
            }
        }
        out
    }

    #[test]
    fn spmm_matches_dense() {
        let mut rng = Pcg::seed(21);
        for _ in 0..8 {
            let m = rng.range(1, 24);
            let k = rng.range(1, 24);
            let f = rng.range(1, 12);
            let a = random_csr(&mut rng, m, k, 0.25);
            let h = random_dense(&mut rng, k, f);
            let got = spmm(&a, &h);
            let want = dense_spmm(&a, &h);
            assert!(got.max_abs_diff(&want) < 1e-4);
        }
    }

    #[test]
    fn spmm_covers_every_lane_tail_width() {
        // The microkernel has a blocked body and a scalar tail: sweep
        // feature widths around the lane boundary so both paths and their
        // seam are exercised.
        let mut rng = Pcg::seed(25);
        let a = random_csr(&mut rng, 20, 15, 0.3);
        for f in [1usize, 2, 7, 8, 9, 15, 16, 17, 24] {
            let h = random_dense(&mut rng, 15, f);
            let got = spmm(&a, &h);
            let want = dense_spmm(&a, &h);
            assert!(got.max_abs_diff(&want) < 1e-4, "f={f}");
        }
    }

    #[test]
    fn spmm_into_writes_segment_ranges_of_a_shared_panel() {
        // Computing each RoBW segment's partial directly into its row
        // range of one panel must equal the whole-matrix product — and
        // must fully overwrite stale panel contents.
        let mut rng = Pcg::seed(26);
        let a = random_csr(&mut rng, 40, 18, 0.25);
        let h = random_dense(&mut rng, 18, 9);
        let want = spmm(&a, &h);
        let f = h.ncols;
        let mut panel = vec![f32::NAN; a.nrows * f];
        let pool = Pool::new(3);
        for (lo, hi) in [(0usize, 13usize), (13, 13), (13, 29), (29, 40)] {
            let sub = a.slice_rows(lo, hi);
            if lo % 2 == 0 {
                spmm_into(&sub, &h, &mut panel[lo * f..hi * f]);
            } else {
                spmm_par_into(&sub, &h, &pool, &mut panel[lo * f..hi * f]);
            }
        }
        assert_eq!(panel, want.data, "segment-wise panel fill == whole product");
    }

    #[test]
    fn spmm_par_matches_serial_exactly() {
        let mut rng = Pcg::seed(23);
        for _ in 0..6 {
            let m = rng.range(1, 30);
            let k = rng.range(1, 30);
            let f = rng.range(1, 10);
            let a = random_csr(&mut rng, m, k, 0.3);
            let h = random_dense(&mut rng, k, f);
            let want = spmm(&a, &h);
            for threads in [1usize, 2, 4, 8] {
                assert_eq!(spmm_par(&a, &h, &Pool::new(threads)), want, "threads={threads}");
            }
        }
    }

    #[test]
    fn spmm_transpose_par_matches_serial_exactly() {
        let mut rng = Pcg::seed(24);
        for _ in 0..6 {
            let m = rng.range(1, 30);
            let k = rng.range(1, 30);
            let f = rng.range(1, 10);
            let a = random_csr(&mut rng, m, k, 0.3);
            let h = random_dense(&mut rng, m, f);
            let want = spmm_transpose(&a, &h);
            for threads in [1usize, 2, 4, 8] {
                assert_eq!(
                    spmm_transpose_par(&a, &h, &Pool::new(threads)),
                    want,
                    "threads={threads}"
                );
            }
        }
    }

    #[test]
    fn transpose_into_accumulates_segment_ranges_exactly() {
        // Scattering each RoBW segment's contribution into one shared
        // accumulator panel must be byte-identical to the whole-matrix
        // transpose product, serial and parallel alike — the property the
        // streamed backward pass's dX accumulation rests on.
        let mut rng = Pcg::seed(27);
        let a = random_csr(&mut rng, 40, 18, 0.25);
        let h = random_dense(&mut rng, 40, 9);
        let want = spmm_transpose(&a, &h);
        let f = h.ncols;
        for threads in [1usize, 2, 4, 8] {
            let pool = Pool::new(threads);
            let mut panel = vec![0f32; a.ncols * f];
            for (lo, hi) in [(0usize, 13usize), (13, 13), (13, 29), (29, 40)] {
                let sub = a.slice_rows(lo, hi);
                let hseg = &h.data[lo * f..hi * f];
                if lo % 2 == 0 {
                    spmm_transpose_into(&sub, hseg, f, &mut panel);
                } else {
                    spmm_transpose_par_into(&sub, hseg, f, &pool, &mut panel);
                }
            }
            assert_eq!(panel, want.data, "threads={threads}");
        }
    }

    #[test]
    fn transpose_spmm_matches_explicit_transpose() {
        let mut rng = Pcg::seed(22);
        let a = random_csr(&mut rng, 15, 11, 0.3);
        let h = random_dense(&mut rng, 15, 7);
        let got = spmm_transpose(&a, &h);
        let at = a.to_csc().to_csr(); // CSC(A) reinterpreted == CSR(Aᵀ) after swap
        // build explicit transpose: swap dims of a
        let mut att = Coo::new(a.ncols, a.nrows);
        for i in 0..a.nrows {
            for (c, v) in a.row(i) {
                att.push(c, i as u32, v);
            }
        }
        let want = spmm(&att.to_csr(), &h);
        assert!(got.max_abs_diff(&want) < 1e-4);
        let _ = at;
    }
}
