//! GCN adjacency normalization (paper Eqs. 1-2):
//! Â = D̂^{-1/2} (A + I) D̂^{-1/2}, with D̂ the degree matrix of A + I.

use super::{Coo, Csr};

/// Build the normalized augmented adjacency Â from a (square) adjacency A.
/// Self-loops are added (A + I); existing self-loop values are summed with 1.
pub fn normalize_adjacency(a: &Csr) -> Csr {
    assert_eq!(a.nrows, a.ncols, "adjacency must be square");
    let n = a.nrows;

    // A + I in COO (cheap; conversion re-sorts + dedups).
    let mut coo = Coo::new(n, n);
    for i in 0..n {
        coo.push(i as u32, i as u32, 1.0);
        for (c, v) in a.row(i) {
            coo.push(i as u32, c, v);
        }
    }
    let a_hat = coo.to_csr();

    // Degrees of A + I (row sums) -> D^-1/2.
    let mut dinv_sqrt = vec![0f64; n];
    for i in 0..n {
        let deg: f64 = a_hat.row(i).map(|(_, v)| v as f64).sum();
        dinv_sqrt[i] = if deg > 0.0 { 1.0 / deg.sqrt() } else { 0.0 };
    }

    // Scale each entry: Â[i,j] = dinv[i] * (A+I)[i,j] * dinv[j].
    let mut out = a_hat;
    for i in 0..n {
        let (lo, hi) = (out.rowptr[i], out.rowptr[i + 1]);
        for p in lo..hi {
            let j = out.colidx[p] as usize;
            out.vals[p] = (dinv_sqrt[i] * out.vals[p] as f64 * dinv_sqrt[j]) as f32;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::Coo;

    fn ring(n: usize) -> Csr {
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            let j = (i + 1) % n;
            coo.push(i as u32, j as u32, 1.0);
            coo.push(j as u32, i as u32, 1.0);
        }
        coo.to_csr()
    }

    #[test]
    fn symmetric_input_gives_symmetric_output() {
        let a = ring(8);
        let ah = normalize_adjacency(&a);
        let d = ah.to_dense();
        for i in 0..8 {
            for j in 0..8 {
                assert!((d[i * 8 + j] - d[j * 8 + i]).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn regular_graph_rows_sum_to_one() {
        // k-regular + self loop: every row of Â sums to exactly 1.
        let a = ring(10);
        let ah = normalize_adjacency(&a);
        for i in 0..10 {
            let s: f32 = ah.row(i).map(|(_, v)| v).sum();
            assert!((s - 1.0).abs() < 1e-5, "row {i} sums to {s}");
        }
    }

    #[test]
    fn isolated_node_keeps_unit_self_loop() {
        let a = Csr::empty(3, 3);
        let ah = normalize_adjacency(&a);
        // A+I = I, degrees 1, Â = I.
        let d = ah.to_dense();
        for i in 0..3 {
            for j in 0..3 {
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((d[i * 3 + j] - want).abs() < 1e-6);
            }
        }
    }

    #[test]
    fn adds_self_loops() {
        let a = ring(6);
        let ah = normalize_adjacency(&a);
        for i in 0..6 {
            assert!(ah.row(i).any(|(c, _)| c as usize == i), "row {i} missing self loop");
        }
        assert_eq!(ah.nnz(), a.nnz() + 6);
    }
}
