//! Schedulers: AIRES's three-phase dynamic scheduling (Algorithm 2) and the
//! three baselines the paper compares against (Table I).
//!
//! Each scheduler turns a [`Workload`] (one dataset + model config + GPU
//! memory constraint) into a DAG of simulator ops modelling one *training
//! epoch* — forward aggregation SpGEMM + combination per layer, plus the
//! backward pass that re-streams the adjacency — and returns the makespan,
//! the per-channel I/O breakdown and the peak GPU residency. The paper's
//! Figures 6-9 and Table III are sweeps over these runs.
//!
//! Host-side compute costs share hooks with the real `runtime::pool`
//! kernels so CLI knobs move the simulated experiments and the executed
//! code together: UCG's CPU share (`CostModel::cpu_secs`) follows
//! `cpu_threads`/`cpu_parallel_eff` (`--threads`), the RoBW partition scan
//! (`Op::CpuPartition`) follows `partition_threads` — set only when the
//! parallel planner `robw_partition_par` is actually selected — and
//! AIRES's Phase II segment-submission overhead follows `prefetch_depth`
//! (`--prefetch-depth`, via `CostModel::staging_exposure`). Defaults keep
//! the calibration serial and every figure unchanged.

pub mod aires;
pub mod etc_sched;
pub mod maxmem;
pub mod ucg;

pub use aires::Aires;
pub use etc_sched::Etc;
pub use maxmem::MaxMemory;
pub use ucg::Ucg;

use crate::graphgen::DatasetStats;
use crate::memsim::sim::OpRecord;
use crate::memsim::{CostModel, IoStats, Sim};

/// Table I feature matrix (asserted by tests; printed by the CLI).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Features {
    /// Row block-wise alignment (complete rows per segment).
    pub alignment: bool,
    /// Pinned-memory DMA transfers.
    pub dma: bool,
    /// Unified-memory fault-driven reads.
    pub um_reads: bool,
    /// Dual-way GDS path (NVMe<->GPU direct).
    pub dual_way: bool,
    /// Algorithm-system co-design (RoBW + three-phase scheduling).
    pub co_design: bool,
}

/// One SpGEMM training workload (paper §V-A model configuration).
#[derive(Debug, Clone)]
pub struct Workload {
    /// Dataset name.
    pub name: String,
    /// Graph vertices (rows/cols of CSR A).
    pub vertices: u64,
    /// Stored non-zeros of CSR A (2x edges for symmetric graphs).
    pub a_nnz: u64,
    /// Feature width (paper default 256).
    pub feat_dim: u64,
    /// Feature sparsity percent (paper default 99%).
    pub b_sparsity_pct: f64,
    /// GPU memory constraint in bytes (Table II col 5).
    pub gpu_mem_bytes: u64,
    /// GCN layers; an epoch streams A `2*layers` times (fwd + bwd).
    pub layers: u32,
    /// Optional calibrated total requirement (Table II col 4). When set,
    /// the output size is derived as `req - A - B` to match the paper's
    /// accounting; otherwise the probabilistic estimator is used.
    pub memory_req_bytes: Option<u64>,
}

impl Workload {
    /// Build from a Table II catalog entry with the paper's model config.
    pub fn from_catalog(d: &DatasetStats, feat_dim: u64, layers: u32) -> Workload {
        Workload {
            name: d.name.to_string(),
            vertices: d.vertices(),
            a_nnz: d.nnz(),
            feat_dim,
            b_sparsity_pct: 99.0,
            gpu_mem_bytes: d.constraint_bytes(),
            layers,
            memory_req_bytes: Some((d.memory_req_gb * 1e9) as u64),
        }
    }

    /// CSR A bytes (vals+colidx @4B, rowptr @8B).
    pub fn a_bytes(&self) -> u64 {
        self.a_nnz * 8 + (self.vertices + 1) * 8
    }

    /// CSC B non-zeros (V x feat at the configured sparsity).
    pub fn b_nnz(&self) -> u64 {
        (self.vertices as f64 * self.feat_dim as f64 * (1.0 - self.b_sparsity_pct / 100.0))
            as u64
    }

    /// CSC B bytes.
    pub fn b_bytes(&self) -> u64 {
        self.b_nnz() * 8 + (self.feat_dim + 1) * 8
    }

    /// Expected output density of C = A·B per the probabilistic model:
    /// P[hit] = 1 − (1 − d_B)^avg_row_nnz.
    pub fn c_density(&self) -> f64 {
        let d_b = 1.0 - self.b_sparsity_pct / 100.0;
        let avg_row = self.a_nnz as f64 / self.vertices as f64;
        1.0 - (1.0 - d_b).powf(avg_row)
    }

    /// Expected CSR C bytes (probabilistic estimator). Note the split:
    /// *traffic* follows this estimate of the real output, while
    /// *feasibility* (`req_bytes`) follows the catalog's calibrated total —
    /// precisely because the baselines' conservative static reservations,
    /// not the real output, are what OOM (the paper's §III-B point).
    pub fn c_bytes(&self) -> u64 {
        let nnz_c = (self.vertices as f64 * self.feat_dim as f64 * self.c_density()) as u64;
        nnz_c * 8 + (self.vertices + 1) * 8
    }

    /// Total working-set requirement (paper Table II "Memory Req.").
    pub fn req_bytes(&self) -> u64 {
        self.memory_req_bytes.unwrap_or_else(|| self.a_bytes() + self.b_bytes() + self.c_bytes())
    }

    /// SpGEMM flops for one aggregation pass: every stored a_ik meets the
    /// non-zeros of B row k (avg feat·d_B), 2 flops per match.
    pub fn spgemm_flops(&self) -> u64 {
        let d_b = 1.0 - self.b_sparsity_pct / 100.0;
        (2.0 * self.a_nnz as f64 * self.feat_dim as f64 * d_b) as u64
    }

    /// Combination flops for one layer: X·W with X = Â·H sparse (its
    /// density follows `c_density`), W dense — gather-GEMM work scales
    /// with nnz(X), not V·f.
    pub fn combine_flops(&self) -> u64 {
        let nnz_x = self.c_bytes() / 8;
        2 * nnz_x * self.feat_dim
    }

    /// Average bytes of one CSR A row (vals+colidx).
    pub fn avg_row_bytes(&self) -> f64 {
        self.a_nnz as f64 / self.vertices as f64 * 8.0
    }

    /// A-stream passes per epoch (fwd + bwd per layer).
    pub fn cycles(&self) -> u64 {
        2 * self.layers as u64
    }
}

/// Outcome of one simulated epoch.
#[derive(Debug, Clone)]
pub struct EpochResult {
    /// Scheduler name (Table I row).
    pub scheduler: &'static str,
    /// Workload/dataset name.
    pub workload: String,
    /// End-to-end per-epoch latency (the paper's headline metric), or
    /// `None` if the scheduler hit OOM ('-' rows in Table III).
    pub makespan_s: Option<f64>,
    /// Why the run OOMed, when it did.
    pub oom: Option<String>,
    /// Per-channel I/O breakdown (Figures 7-8).
    pub io: IoStats,
    /// Peak GPU bytes the schedule required.
    pub gpu_peak_bytes: u64,
    /// Full op log (drives `memsim::trace::chrome_trace` and debugging).
    pub log: Vec<OpRecord>,
}

impl EpochResult {
    /// An OOM outcome (Table III '-' cell).
    pub fn oom(scheduler: &'static str, workload: &Workload, why: String) -> Self {
        EpochResult {
            scheduler,
            workload: workload.name.clone(),
            makespan_s: None,
            oom: Some(why),
            io: IoStats::default(),
            gpu_peak_bytes: 0,
            log: Vec::new(),
        }
    }

    /// A completed outcome summarizing a finished simulation.
    pub fn ok(scheduler: &'static str, workload: &Workload, sim: &Sim, peak: u64) -> Self {
        EpochResult {
            scheduler,
            workload: workload.name.clone(),
            makespan_s: Some(sim.makespan()),
            oom: None,
            io: IoStats::from_sim(sim),
            gpu_peak_bytes: peak,
            log: sim.log.clone(),
        }
    }
}

// ---------------------------------------------------------------------------
// Calibration constants (single source; see DESIGN.md §Simulator cost model).
// The OOM fractions reproduce the Table III feasibility boundaries: the
// paper's static allocators reserve most of the full working set (req),
// while ETC's batching lowers the resident minimum and AIRES needs only
// B + one RoBW block + the modelled output working set.
// ---------------------------------------------------------------------------

/// Minimum GPU residency of the static allocators (MaxMemory, UCG) as a
/// fraction of the total working set.
pub const STATIC_MIN_FRAC: f64 = 0.84;
/// Minimum GPU residency of ETC's batched allocator.
pub const ETC_MIN_FRAC: f64 = 0.72;
/// Pageable (non-pinned) memcpy bandwidth penalty (MaxMemory lacks DMA).
pub const PAGEABLE_BW_FRAC: f64 = 0.8;
/// Max simulator ops per stream (real segment counts can reach 1e5 on
/// paper-scale graphs; ops are coalesced to keep the log compact while
/// preserving totals).
pub const MAX_STREAM_OPS: usize = 64;

/// Split `total` bytes into at most `max_ops` near-equal chunks.
pub(crate) fn chunks(total: u64, n: usize) -> Vec<u64> {
    if total == 0 {
        return Vec::new();
    }
    let n = n.max(1) as u64;
    let base = total / n;
    let mut rem = total % n;
    (0..n)
        .map(|_| {
            let extra = if rem > 0 { rem -= 1; 1 } else { 0 };
            base + extra
        })
        .filter(|&b| b > 0)
        .collect()
}

/// A scheduling policy under evaluation.
pub trait Scheduler {
    fn name(&self) -> &'static str;
    /// Table I row for this policy.
    fn features(&self) -> Features;
    /// Simulate one training epoch.
    fn run_epoch(&self, w: &Workload, cm: &CostModel) -> EpochResult;
}

/// All four policies in the paper's comparison order.
pub fn all_schedulers() -> Vec<Box<dyn Scheduler>> {
    vec![Box::new(MaxMemory), Box::new(Ucg), Box::new(Etc), Box::new(Aires)]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::catalog::by_name;

    #[test]
    fn workload_from_catalog_carries_table2() {
        let d = by_name("kV1r").unwrap();
        let w = Workload::from_catalog(d, 256, 1);
        assert_eq!(w.vertices, 214_000_000);
        assert_eq!(w.a_nnz, 2 * 465_410_000);
        assert_eq!(w.gpu_mem_bytes, 23_000_000_000);
        // Calibrated C: req − A − B must be positive for every dataset.
        for d in crate::graphgen::CATALOG.iter() {
            let w = Workload::from_catalog(d, 256, 1);
            assert!(w.c_bytes() > 0, "{}", d.name);
            assert!(w.req_bytes() > w.gpu_mem_bytes, "{} must be out-of-core", d.name);
        }
    }

    #[test]
    fn c_density_increases_with_degree() {
        let mut w = Workload::from_catalog(by_name("rUSA").unwrap(), 256, 1);
        w.memory_req_bytes = None;
        let sparse_c = w.c_density();
        let mut w2 = Workload::from_catalog(by_name("socLJ1").unwrap(), 256, 1);
        w2.memory_req_bytes = None;
        assert!(w2.c_density() > sparse_c, "denser graph -> denser output");
    }

    #[test]
    fn flops_scale_with_feat_dim() {
        let d = by_name("kP1a").unwrap();
        let w64 = {
            let mut w = Workload::from_catalog(d, 64, 1);
            w.memory_req_bytes = None;
            w
        };
        let w256 = {
            let mut w = Workload::from_catalog(d, 256, 1);
            w.memory_req_bytes = None;
            w
        };
        assert!(w256.spgemm_flops() > 3 * w64.spgemm_flops());
    }

    #[test]
    fn table1_feature_matrix() {
        // Exactly the paper's Table I.
        let m = MaxMemory.features();
        assert!(!m.alignment && !m.dual_way && !m.co_design);
        let u = Ucg.features();
        assert!(!u.alignment && !u.dma && u.um_reads && !u.dual_way && !u.co_design);
        let e = Etc.features();
        assert!(!e.alignment && e.dma && !e.um_reads && !e.dual_way && !e.co_design);
        let a = Aires.features();
        assert!(a.alignment && a.dma && !a.um_reads && a.dual_way && a.co_design);
    }
}
