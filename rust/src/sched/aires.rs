//! AIRES three-phase dynamic scheduling (paper §III-B, Algorithm 2, Fig. 5).
//!
//! * **Phase I** — dual-way load: CSC B flows NVMe→GPU directly via GDS
//!   while CSR A flows NVMe→host and is RoBW-partitioned on the CPU
//!   (overlapped chunk-wise: partition(i) starts as soon as load(i) lands).
//! * **Phase II** — RoBW segments sized by the Eq. 5-7 output model stream
//!   host→GPU via pinned DMA; segment k+1's transfer overlaps segment k's
//!   kernel; outputs are dynamically allocated (model-guided cudaMalloc)
//!   and *stay in GPU memory*.
//! * **Phase III** — the output remains resident for the next SpGEMM cycle
//!   (no DtoH between layers / fwd-bwd); any overflow spills GPU→NVMe
//!   directly via GDS (the second leg of the dual-way path), overlapped
//!   with compute; the final result is written back the same way.
//!
//! Extra behaviours the evaluation exposes:
//! * leftover GPU memory caches hot RoBW segments across cycles ("the data
//!   remains within the GPU for immediate access in subsequent SpGEMM
//!   cycles"), which is what collapses AIRES's PCIe traffic in Fig. 7;
//! * when even CSC B does not fit (deep Table III constraints), B is
//!   panelled through GDS instead of OOMing — latency degrades gracefully
//!   (5.01 s → 5.05 s in the paper), feasibility does not.

use super::{chunks, EpochResult, Features, Scheduler, Workload, MAX_STREAM_OPS};
use crate::memsim::{CostModel, GpuMem, Op, Sim};

/// Marker type implementing the AIRES policy.
pub struct Aires;

/// Minimum RoBW block budget per array (Eq. 7's p): below this the
/// transfer latency floor dominates and the schedule stops improving.
const MIN_BLOCK_BYTES: u64 = 16 << 20;
/// Maximum useful block budget: past ~1 GiB per array the pipeline is
/// bandwidth-bound and bigger blocks only reduce overlap granularity.
const MAX_BLOCK_BYTES: u64 = 1 << 30;


/// The memory plan AIRES derives from the Eq. 5-7 model.
#[derive(Debug, Clone, Copy)]
pub struct MemPlan {
    /// Eq. 7 block budget per CSR array (bytes).
    pub p: u64,
    /// Resident CSC B bytes (may be a panel under pressure).
    pub m_b: u64,
    /// Resident output working set.
    pub m_c: u64,
    /// Number of B panels (1 = fully resident).
    pub b_panels: u64,
    /// Output bytes spilled via GDS per cycle.
    pub spill: u64,
    /// A-segment cache fraction across cycles.
    pub cache_frac: f64,
}

impl Aires {
    /// Derive the memory plan for a workload; `None` means infeasible
    /// (which for AIRES requires a pathologically small constraint).
    pub fn plan(w: &Workload) -> Option<MemPlan> {
        let cap = w.gpu_mem_bytes;
        let m_b_full = w.b_bytes();
        let c_full = w.c_bytes();

        let mut m_b = m_b_full;
        let mut b_panels = 1u64;
        // Maximize resident C first (Phase III keeps the output in GPU
        // memory); panel B through GDS if even a minimal block won't fit.
        let m_c;
        loop {
            if cap > m_b + 3 * MIN_BLOCK_BYTES {
                m_c = c_full.min(cap - m_b - 3 * MIN_BLOCK_BYTES);
                break;
            }
            if b_panels >= 64 {
                return None;
            }
            b_panels *= 2;
            m_b = m_b_full / b_panels;
        }
        let p = (cap.saturating_sub(m_b + m_c) / 3).clamp(MIN_BLOCK_BYTES, MAX_BLOCK_BYTES);
        let spill = c_full.saturating_sub(m_c);
        let resident = m_b + m_c + 3 * p;
        let spare = cap.saturating_sub(resident);
        let cache_frac = (spare as f64 / w.a_bytes() as f64).min(1.0);
        Some(MemPlan { p, m_b, m_c, b_panels, spill, cache_frac })
    }

    /// One-time preprocessing cost (Phase I of the *first* epoch): load A
    /// from NVMe and RoBW-partition it on the CPU, chunk-overlapped.
    /// Amortized across training, reported separately in EXPERIMENTS.md.
    pub fn prep_time(w: &Workload, cm: &CostModel) -> f64 {
        let mut sim = Sim::new();
        let mut load_done = 0.0f64;
        let mut part_done = 0.0f64;
        for c in chunks(w.a_bytes(), 8) {
            load_done = sim.transfer(cm, Op::NvmeToHost, c, load_done, "A load");
            part_done = sim.transfer(cm, Op::CpuPartition, c, load_done.max(part_done), "RoBW");
        }
        sim.makespan()
    }
}

impl Scheduler for Aires {
    fn name(&self) -> &'static str {
        "AIRES"
    }

    fn features(&self) -> Features {
        Features { alignment: true, dma: true, um_reads: false, dual_way: true, co_design: true }
    }

    fn run_epoch(&self, w: &Workload, cm: &CostModel) -> EpochResult {
        let Some(plan) = Self::plan(w) else {
            return EpochResult::oom(
                self.name(),
                w,
                format!("no viable RoBW block under constraint {}", w.gpu_mem_bytes),
            );
        };
        let mut mem = GpuMem::new(w.gpu_mem_bytes);
        if let Err(e) = mem.alloc(plan.m_b + plan.m_c + 3 * plan.p, "B + C + RoBW block") {
            return EpochResult::oom(self.name(), w, e.to_string());
        }
        // The segment cache occupies the spare it was planned from.
        let cache_bytes = ((w.a_bytes() as f64) * plan.cache_frac) as u64;
        let _ = mem.alloc(cache_bytes.min(mem.available()), "RoBW segment cache");

        let mut sim = Sim::new();
        let a = w.a_bytes();
        let m_b_full = w.b_bytes();

        // ---- Phase I: dual-way load -------------------------------------
        // Steady-state epoch: CSR A is host-resident and RoBW-partitioned
        // (one-time preprocessing, measured separately by `prep_time`);
        // the initial feature panel B is fetched NVMe→GPU via GDS.
        let mut b_done = 0.0f64;
        for c in chunks(m_b_full, 8) {
            b_done = sim.transfer(cm, Op::GdsRead, c, 0.0, "B load (GDS)");
        }
        let part_done = 0.0f64; // RoBW segments already staged in host mem

        // Dynamic output allocation: one model-guided malloc up front.
        let mut t = sim.gpu_malloc(cm, b_done.max(part_done), "C alloc (model)");

        // ---- Phase II: pipelined RoBW streaming, per cycle --------------
        let flops_per_cycle = w.spgemm_flops();
        let mut spill_ready = t;
        for cycle in 0..w.cycles() {
            let stream_bytes = if cycle == 0 {
                a
            } else {
                ((a as f64) * (1.0 - plan.cache_frac)) as u64
            };
            // Spilled output from the previous cycle returns over PCIe
            // H2D (host RAM is the spill tier; the NVMe controller stays
            // dedicated to the GDS segment stream).
            if plan.spill > 0 && cycle > 0 {
                for c in chunks(plan.spill, 8) {
                    spill_ready = sim.transfer(cm, Op::HtoD, c, spill_ready, "C spill in");
                }
            }
            // Real segment count charges per-segment submission overheads
            // (cudaMalloc + DMA setup), even though the op log coalesces.
            // The prefetch pipeline (`runtime::prefetch`) stages segments
            // ahead of the kernel, so only the staging_exposure share of
            // that overhead serializes with compute (neutral at depth 1).
            let n_real = stream_bytes.div_ceil((3 * plan.p).max(1)).max(1);
            let overhead_s =
                n_real as f64 * (cm.gpu_malloc_s + cm.op_latency_s) * cm.staging_exposure();
            let segs = chunks(stream_bytes, MAX_STREAM_OPS);
            // Kernel work: GPU memory traffic covers all three operands
            // every cycle, regardless of where they were sourced from.
            let cycle_kernel_bytes = a + w.b_bytes() + w.c_bytes();
            let stream_share = 1.0 - if cycle == 0 { 0.0 } else { plan.cache_frac };
            let flops_seg =
                ((flops_per_cycle as f64) * stream_share) as u64 / segs.len().max(1) as u64;
            let bytes_seg =
                ((cycle_kernel_bytes as f64) * stream_share) as u64 / segs.len().max(1) as u64;
            let cached_flops = ((flops_per_cycle as f64) * (1.0 - stream_share)) as u64;
            let cached_bytes = ((cycle_kernel_bytes as f64) * (1.0 - stream_share)) as u64;

            let mut kernel_done = sim.occupy(Op::GpuMalloc, overhead_s, t, "dyn alloc (n segs)");
            for seg in &segs {
                // Pipelined: HtoD(i+1) only waits on the DMA engine;
                // kernel(i) waits on its own transfer + kernel(i-1).
                // Steady state streams the aligned segments NVMe→GPU via
                // GDS (the one-time RoBW pass wrote them back aligned), so
                // the PCIe lanes stay almost silent — the paper's Fig. 7.
                let seg_in = sim.transfer(cm, Op::GdsRead, *seg, part_done, "RoBW seg (GDS)");
                kernel_done =
                    sim.gpu_kernel(cm, flops_seg, bytes_seg, kernel_done.max(seg_in), "SpGEMM seg");
            }
            if cached_flops > 0 || cached_bytes > 0 {
                kernel_done =
                    sim.gpu_kernel(cm, cached_flops, cached_bytes, kernel_done, "SpGEMM cached");
            }
            kernel_done = kernel_done.max(spill_ready);
            // Combination (dense X·W tiles on the MXU-path artifact).
            t = sim.gpu_dense(cm, w.combine_flops(), kernel_done, "combine");
            // B panelling (tight memory): re-fetch evicted panels via GDS.
            if plan.b_panels > 1 && cycle + 1 < w.cycles() {
                let mut pt = t;
                for c in chunks(m_b_full - plan.m_b, 8) {
                    pt = sim.transfer(cm, Op::GdsRead, c, pt, "B panel refetch");
                }
                t = t.max(pt);
            }
            // Phase III (intra-epoch): resident C stays as next input; the
            // overflow spills to host RAM over the idle D2H engine,
            // overlapped with the next cycle's GDS stream.
            if plan.spill > 0 {
                let mut st = t;
                for c in chunks(plan.spill, 8) {
                    st = sim.transfer(cm, Op::DtoH, c, st, "C spill out");
                }
                spill_ready = st;
            }
        }

        // ---- Phase III: the output stays GPU-resident for the next epoch
        // (spilled share is already on NVMe via GDS); no further writeback
        // on the per-epoch path.
        let _ = t;

        EpochResult::ok(self.name(), w, &sim, mem.peak)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::catalog::by_name;

    fn wl(name: &str) -> Workload {
        Workload::from_catalog(by_name(name).unwrap(), 256, 1)
    }

    #[test]
    fn runs_every_catalog_dataset() {
        let cm = CostModel::default();
        for d in crate::graphgen::CATALOG.iter() {
            let w = Workload::from_catalog(d, 256, 1);
            let r = Aires.run_epoch(&w, &cm);
            assert!(r.oom.is_none(), "{}: {:?}", d.name, r.oom);
            assert!(r.makespan_s.unwrap() > 0.0);
        }
    }

    #[test]
    fn survives_table3_tightest_constraints() {
        // Table III: AIRES completes at 19 GB (kV1r), 12 GB (kP1a),
        // 8 GB (socLJ1) where every baseline OOMs.
        let cm = CostModel::default();
        for (name, cap_gb) in [("kV1r", 19.0), ("kP1a", 12.0), ("socLJ1", 8.0)] {
            let mut w = wl(name);
            w.gpu_mem_bytes = (cap_gb * 1e9) as u64;
            let r = Aires.run_epoch(&w, &cm);
            assert!(r.oom.is_none(), "{name}@{cap_gb}GB: {:?}", r.oom);
        }
    }

    #[test]
    fn latency_degrades_gracefully_with_memory() {
        // Table III: AIRES 4.95 → 5.01 → 5.05 s as kV1r shrinks 24→21→19:
        // small, monotone degradation.
        let cm = CostModel::default();
        let mut last = 0.0;
        let mut first = 0.0;
        for (i, cap_gb) in [24.0, 21.0, 19.0].iter().enumerate() {
            let mut w = wl("kV1r");
            w.gpu_mem_bytes = (cap_gb * 1e9) as u64;
            let t = Aires.run_epoch(&w, &cm).makespan_s.unwrap();
            if i == 0 {
                first = t;
            }
            assert!(t + 1e-9 >= last, "latency must not improve with less memory");
            last = t;
        }
        assert!(last / first < 1.35, "degradation should be graceful: {first} -> {last}");
    }

    #[test]
    fn uses_gds_both_ways() {
        let cm = CostModel::default();
        let r = Aires.run_epoch(&wl("kP1a"), &cm);
        assert!(r.io.get("GdsRead").bytes > 0, "B must ride GDS");
        assert!(r.io.get("GdsRead").bytes >= wl("kP1a").a_bytes(), "A segments ride GDS");
        assert_eq!(r.io.get("UM").bytes, 0, "AIRES never touches UM");
    }

    #[test]
    fn pcie_traffic_is_a_stream_only() {
        // Fig. 7: AIRES GPU-CPU traffic collapses to (uncached) A streaming.
        let cm = CostModel::default();
        let w = wl("kA2a");
        let r = Aires.run_epoch(&w, &cm);
        let pcie = r.io.gpu_cpu_bytes();
        assert!(
            pcie <= w.a_bytes() * w.cycles(),
            "pcie {} should not exceed full A restreaming",
            pcie
        );
        // Only the (bounded) output spill may ride D2H.
        let plan = Aires::plan(&w).unwrap();
        assert!(r.io.get("DtoH").bytes <= plan.spill * w.cycles());
    }

    #[test]
    fn plan_prefers_full_c_when_room() {
        let mut w = wl("rUSA"); // smallest dataset
        w.gpu_mem_bytes = 64_000_000_000; // plenty of memory
        let plan = Aires::plan(&w).unwrap();
        assert_eq!(plan.spill, 0, "no spill when C fits");
        assert_eq!(plan.b_panels, 1);
        assert!(plan.cache_frac > 0.99, "A fully cached with spare memory");
    }

    #[test]
    fn prefetch_hook_neutral_at_depth_one_and_never_slower_deeper() {
        let w = wl("kP1a");
        let t_default = Aires.run_epoch(&w, &CostModel::default()).makespan_s.unwrap();
        let mut d1 = CostModel::default();
        d1.prefetch_depth = 1.0;
        assert_eq!(
            Aires.run_epoch(&w, &d1).makespan_s.unwrap(),
            t_default,
            "default calibration is the depth-1 serial staging baseline"
        );
        let mut last = t_default;
        for depth in [2.0, 4.0] {
            let mut cm = CostModel::default();
            cm.prefetch_depth = depth;
            let t = Aires.run_epoch(&w, &cm).makespan_s.unwrap();
            assert!(t <= last + 1e-12, "depth {depth} must not slow the epoch");
            last = t;
        }
    }

    #[test]
    fn plan_panels_b_only_under_extreme_pressure() {
        let mut w = wl("kV1r");
        w.gpu_mem_bytes = 3_000_000_000; // 3 GB: below even resident B
        let plan = Aires::plan(&w).unwrap();
        assert!(plan.b_panels > 1, "B must panel at 3 GB");
    }
}
