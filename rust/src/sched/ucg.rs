//! UCG baseline (Lin, Deng & Prasanna, CF'24 — paper ref [22]): "a unified
//! CPU-GPU protocol [...] dynamically balancing the workload between CPU
//! and GPU", with GPU feature caching, unified shared memory and
//! communication/computation overlap.
//!
//! Behavioural model (Table I row: UM reads, no DMA, no alignment, no
//! dual-way): operands are accessed through CUDA unified memory — fault-
//! driven migration at UM bandwidth with per-burst latency and an
//! oversubscription amplification when the working set exceeds the
//! constraint; a slice of the SpGEMM runs on the CPU concurrently; feature
//! reads hit a GPU-resident cache when it fits.

use super::{chunks, EpochResult, Features, Scheduler, Workload, STATIC_MIN_FRAC};
use crate::memsim::{CostModel, GpuMem, Op, Sim};

/// Marker type implementing the UCG policy.
pub struct Ucg;

/// Fraction of SpGEMM work offloaded to the CPU (UCG's dynamic balancing
/// settles near the CPU/GPU throughput ratio for sparse kernels).
const CPU_SHARE: f64 = 0.12;
/// GPU memory share UCG dedicates to the feature cache.
const CACHE_SHARE: f64 = 0.25;
/// Extra UM traffic per unit of oversubscription (fault thrashing).
const THRASH_GAIN: f64 = 0.35;
/// UM pipeline depth (chunks in flight).
const UM_CHUNKS: usize = 48;

impl Scheduler for Ucg {
    fn name(&self) -> &'static str {
        "UCG"
    }

    fn features(&self) -> Features {
        Features { alignment: false, dma: false, um_reads: true, dual_way: false, co_design: false }
    }

    fn run_epoch(&self, w: &Workload, cm: &CostModel) -> EpochResult {
        // UM does not remove the resident minimum: UCG's allocator still
        // pins most of the working set (same static fraction as MaxMemory;
        // the paper's Table III shows identical OOM boundaries).
        let min_resident = (w.req_bytes() as f64 * STATIC_MIN_FRAC) as u64;
        if w.gpu_mem_bytes < min_resident {
            return EpochResult::oom(
                self.name(),
                w,
                format!("UM residency {} exceeds constraint {}", min_resident, w.gpu_mem_bytes),
            );
        }
        let mut mem = GpuMem::new(w.gpu_mem_bytes);
        mem.alloc(min_resident, "UM working set").expect("checked above");

        let mut sim = Sim::new();
        let a = w.a_bytes();
        let b = w.b_bytes();
        let c = w.c_bytes();

        // Steady-state epoch: A stays in unified host memory; the feature
        // panel is re-faulted from storage each epoch.
        let mut loaded = 0.0f64;
        for ch in chunks(b, 4) {
            loaded = sim.transfer(cm, Op::NvmeToHost, ch, loaded, "B from NVMe");
        }

        // Feature cache: hits skip UM migration.
        let cache_bytes = ((w.gpu_mem_bytes as f64) * CACHE_SHARE) as u64;
        let cache_frac = (cache_bytes as f64 / b as f64).min(1.0);

        // Oversubscription amplification.
        let oversub = (w.req_bytes() as f64 / w.gpu_mem_bytes as f64 - 1.0).max(0.0);
        let amp = 1.0 + THRASH_GAIN * oversub.min(1.0);

        let flops = w.spgemm_flops();
        let gpu_flops = ((flops as f64) * (1.0 - CPU_SHARE)) as u64;
        let cpu_flops = ((flops as f64) * CPU_SHARE) as u64;

        let mut t = loaded;
        for cycle in 0..w.cycles() {
            // UM traffic this cycle: A + uncached B (features on even
            // cycles, gradients on odd) + the share of C that thrashes.
            let b_cycle = if cycle % 2 == 0 {
                ((b as f64) * (1.0 - cache_frac)) as u64
            } else {
                c
            };
            let um_bytes = ((a + b_cycle + c / 2) as f64 * amp) as u64;
            let um = chunks(um_bytes, UM_CHUNKS);
            let flops_chunk = gpu_flops / um.len().max(1) as u64;
            let bytes_chunk = (a + b + c) / um.len().max(1) as u64;
            // CPU share runs concurrently with the whole cycle. Its cost
            // goes through cm.cpu_secs, so it scales with the cpu_threads
            // hook (runtime::pool's row-range kernels are what the CPU
            // share executes).
            sim.cpu_compute(cm, cpu_flops, t, "CPU share");
            let mut kernel_done = t;
            for ch in um {
                // Overlapped: fault burst for chunk i+1 proceeds while the
                // kernel for chunk i runs (different resources).
                let fault = sim.transfer(cm, Op::UmFault, ch, t, "UM migrate");
                kernel_done =
                    sim.gpu_kernel(cm, flops_chunk, bytes_chunk, kernel_done.max(fault), "SpGEMM");
            }
            t = sim.gpu_dense(cm, w.combine_flops(), kernel_done, "combine");
        }
        let _ = t;

        EpochResult::ok(self.name(), w, &sim, mem.peak)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::catalog::by_name;

    fn wl(name: &str) -> Workload {
        Workload::from_catalog(by_name(name).unwrap(), 256, 1)
    }

    #[test]
    fn runs_at_table2_constraints() {
        let cm = CostModel::default();
        for d in crate::graphgen::CATALOG.iter() {
            let w = Workload::from_catalog(d, 256, 1);
            assert!(Ucg.run_epoch(&w, &cm).oom.is_none(), "{}", d.name);
        }
    }

    #[test]
    fn ooms_like_maxmemory_in_table3() {
        let cm = CostModel::default();
        for (name, cap_gb) in [("kV1r", 21.0), ("kP1a", 14.0), ("socLJ1", 10.0)] {
            let mut w = wl(name);
            w.gpu_mem_bytes = (cap_gb * 1e9) as u64;
            assert!(Ucg.run_epoch(&w, &cm).oom.is_some(), "{name}@{cap_gb}GB");
        }
    }

    #[test]
    fn traffic_is_um_not_memcpy() {
        let cm = CostModel::default();
        let r = Ucg.run_epoch(&wl("kP1a"), &cm);
        assert!(r.io.get("UM").bytes > 0);
        assert_eq!(r.io.get("HtoD").bytes, 0, "UCG reads via UM, not cudaMemcpy");
        assert_eq!(r.io.gpu_ssd_bytes(), 0, "no GDS");
    }

    #[test]
    fn cpu_share_overlaps() {
        let cm = CostModel::default();
        let r = Ucg.run_epoch(&wl("kU1a"), &cm);
        assert!(r.io.get("CpuCompute").secs > 0.0);
    }

    #[test]
    fn um_amplification_under_pressure() {
        // Tighter memory -> more UM traffic for the same workload.
        let cm = CostModel::default();
        let d = by_name("kU1a").unwrap();
        let w_loose = {
            let mut w = Workload::from_catalog(d, 256, 1);
            w.gpu_mem_bytes = (7.9 * 1e9) as u64;
            w
        };
        let w_tight = {
            let mut w = Workload::from_catalog(d, 256, 1);
            w.gpu_mem_bytes = (7.0 * 1e9) as u64;
            w
        };
        let loose = Ucg.run_epoch(&w_loose, &cm);
        let tight = Ucg.run_epoch(&w_tight, &cm);
        assert!(tight.io.get("UM").bytes > loose.io.get("UM").bytes);
    }
}
