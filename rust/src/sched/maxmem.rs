//! MaxMemory baseline (paper §V-A): "a naive static method that stores a
//! maximum equal amount of both the adjacency matrix and the feature matrix
//! data in GPU memory, with the remainder stored in CPU memory."
//!
//! Behavioural model (Table I row: no alignment, no DMA, no UM, no
//! dual-way): everything moves NVMe→host→GPU over pageable memcpy; A is
//! segmented at byte granularity (partial rows → the Fig. 3 merge
//! round-trip); the output is statically over-reserved; nothing overlaps —
//! each op waits for the previous one.

use super::{
    chunks, EpochResult, Features, Scheduler, Workload, MAX_STREAM_OPS, PAGEABLE_BW_FRAC,
    STATIC_MIN_FRAC,
};
use crate::memsim::{CostModel, GpuMem, Op, Sim};

/// Marker type implementing the MaxMemory policy.
pub struct MaxMemory;

impl Scheduler for MaxMemory {
    fn name(&self) -> &'static str {
        "MaxMemory"
    }

    fn features(&self) -> Features {
        Features { alignment: false, dma: false, um_reads: false, dual_way: false, co_design: false }
    }

    fn run_epoch(&self, w: &Workload, cm: &CostModel) -> EpochResult {
        // Static allocation: the planner reserves most of the working set
        // up front; below STATIC_MIN_FRAC of it, cudaMalloc fails.
        let min_resident = (w.req_bytes() as f64 * STATIC_MIN_FRAC) as u64;
        if w.gpu_mem_bytes < min_resident {
            return EpochResult::oom(
                self.name(),
                w,
                format!(
                    "static reservation {} exceeds constraint {}",
                    min_resident, w.gpu_mem_bytes
                ),
            );
        }
        let mut mem = GpuMem::new(w.gpu_mem_bytes);
        mem.alloc(min_resident, "static A/B/C reservation").expect("checked above");

        // Pageable transfers: apply the non-pinned bandwidth penalty by
        // inflating byte counts is wrong (it would distort Fig. 7 volumes),
        // so scale the model's PCIe rates via a local CostModel instead.
        let mut cm_pg = cm.clone();
        cm_pg.pcie_h2d_gbps *= PAGEABLE_BW_FRAC;
        cm_pg.pcie_d2h_gbps *= PAGEABLE_BW_FRAC;

        let mut sim = Sim::new();
        let a = w.a_bytes();
        let b = w.b_bytes();
        let c = w.c_bytes();

        // Steady-state epoch: A is host-resident; the feature panel is
        // re-read from storage each epoch (no Phase-III-style residency).
        let mut t = 0.0f64;
        for ch in chunks(b, 4) {
            t = sim.transfer(cm, Op::NvmeToHost, ch, t, "B from NVMe");
        }

        // Equal split: half the GPU for the feature panel, half for A + C.
        let a_seg = (w.gpu_mem_bytes / 4).max(1);
        let n_segs = a.div_ceil(a_seg).max(1);

        // Merge overhead per segment boundary: the cut lands mid-row, the
        // partial tail (half an average row) round-trips to host.
        let partial_bytes = (w.avg_row_bytes() / 2.0) as u64;

        let flops = w.spgemm_flops();
        let cycle_kernel_bytes = a + b + c;
        for cycle in 0..w.cycles() {
            // B-side operand for this cycle: features on the way down,
            // gradient (C-sized) on the way back. Fully re-sent, pageable.
            let b_cycle = if cycle % 2 == 0 { b } else { c };
            for ch in chunks(b_cycle, 4) {
                t = sim.transfer(&cm_pg, Op::HtoD, ch, t, "B panel");
            }
            // A streamed in byte-granular segments, strictly serially:
            // HtoD -> malloc -> kernel -> C slice out, nothing overlaps.
            let seg_ops = chunks(a, MAX_STREAM_OPS.min(n_segs as usize));
            let flops_seg = flops / seg_ops.len().max(1) as u64;
            let bytes_seg = cycle_kernel_bytes / seg_ops.len().max(1) as u64;
            let segs_per_op = (n_segs as usize).div_ceil(seg_ops.len().max(1)) as u64;
            for seg in &seg_ops {
                t = sim.transfer(&cm_pg, Op::HtoD, *seg, t, "A seg");
                t = sim.gpu_malloc(cm, t, "static C slice");
                t = sim.gpu_kernel(cm, flops_seg, bytes_seg, t, "SpGEMM seg");
                t = sim.transfer(
                    &cm_pg,
                    Op::DtoH,
                    c / seg_ops.len().max(1) as u64,
                    t,
                    "C slice out",
                );
                // Fig. 3 merge round-trip, once per real boundary.
                let merge = partial_bytes * segs_per_op;
                if merge > 0 {
                    t = sim.transfer(&cm_pg, Op::DtoH, merge, t, "partial row back");
                    t = sim.transfer(cm, Op::HostMemcpy, 2 * merge, t, "merge partial");
                    t = sim.transfer(&cm_pg, Op::HtoD, merge, t, "resend merged");
                }
            }
            // Combination matmul (dense-rate tiles).
            t = sim.gpu_dense(cm, w.combine_flops(), t, "combine");
        }
        // Output stays in host memory for the next epoch (no per-epoch
        // NVMe writeback for any policy).
        let _ = t;

        EpochResult::ok(self.name(), w, &sim, mem.peak)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::catalog::by_name;

    fn wl(name: &str) -> Workload {
        Workload::from_catalog(by_name(name).unwrap(), 256, 1)
    }

    #[test]
    fn runs_at_table2_constraints() {
        let cm = CostModel::default();
        for d in crate::graphgen::CATALOG.iter() {
            let w = Workload::from_catalog(d, 256, 1);
            let r = MaxMemory.run_epoch(&w, &cm);
            assert!(r.oom.is_none(), "{} should fit at its Table II constraint", d.name);
        }
    }

    #[test]
    fn ooms_at_table3_second_level() {
        // Table III '-' rows: kV1r@21, kP1a@14, socLJ1@10.
        let cm = CostModel::default();
        for (name, cap_gb) in [("kV1r", 21.0), ("kP1a", 14.0), ("socLJ1", 10.0)] {
            let mut w = wl(name);
            w.gpu_mem_bytes = (cap_gb * 1e9) as u64;
            let r = MaxMemory.run_epoch(&w, &cm);
            assert!(r.oom.is_some(), "{name}@{cap_gb}GB must OOM");
        }
    }

    #[test]
    fn no_gds_no_um() {
        let cm = CostModel::default();
        let r = MaxMemory.run_epoch(&wl("kP1a"), &cm);
        assert_eq!(r.io.gpu_ssd_bytes(), 0);
        assert_eq!(r.io.get("UM").bytes, 0);
        assert!(r.io.get("HtoD").bytes > 0);
        assert!(r.io.get("DtoH").bytes > 0, "partial rows + C slices go back");
    }

    #[test]
    fn merge_traffic_present() {
        // The Fig. 3 pathology: DtoH traffic beyond the C slices.
        let cm = CostModel::default();
        let w = wl("kV2a");
        let r = MaxMemory.run_epoch(&w, &cm);
        let dtoh = r.io.get("DtoH").bytes;
        assert!(dtoh > w.c_bytes(), "DtoH {} must include partial-row merges", dtoh);
    }
}
