//! ETC baseline (Gao et al., VLDB'24 — paper ref [16]): the state-of-the-art
//! batching scheme with a three-step data access policy and an inter-batch
//! pipeline.
//!
//! Behavioural model (Table I row: DMA yes, no alignment, no UM, no
//! dual-way): pinned-DMA transfers at full PCIe rate; the feature matrix is
//! transferred once per epoch and kept resident (the dedup policy); A moves
//! in large batches whose H2D overlaps the previous batch's kernel (the
//! inter-batch pipeline); the output is statically reserved at the size of
//! the larger compressed operand, and batch cuts still land mid-row (no
//! alignment), leaving a reduced — but present — merge round-trip.

use super::{chunks, EpochResult, Features, Scheduler, Workload, ETC_MIN_FRAC, MAX_STREAM_OPS};
use crate::memsim::{CostModel, GpuMem, Op, Sim};

/// Marker type implementing the ETC policy.
pub struct Etc;

impl Scheduler for Etc {
    fn name(&self) -> &'static str {
        "ETC"
    }

    fn features(&self) -> Features {
        Features { alignment: false, dma: true, um_reads: false, dual_way: false, co_design: false }
    }

    fn run_epoch(&self, w: &Workload, cm: &CostModel) -> EpochResult {
        let min_resident = (w.req_bytes() as f64 * ETC_MIN_FRAC) as u64;
        if w.gpu_mem_bytes < min_resident {
            return EpochResult::oom(
                self.name(),
                w,
                format!(
                    "batch reservation {} exceeds constraint {}",
                    min_resident, w.gpu_mem_bytes
                ),
            );
        }
        let mut mem = GpuMem::new(w.gpu_mem_bytes);
        mem.alloc(min_resident, "B + batch + static C reservation").expect("checked above");

        let mut sim = Sim::new();
        let a = w.a_bytes();
        let b = w.b_bytes();
        let c = w.c_bytes();

        // Steady-state epoch: A host-resident; features re-read from
        // storage each epoch before the one-time H2D (dedup policy).
        let mut t = 0.0f64;
        for ch in chunks(b, 4) {
            t = sim.transfer(cm, Op::NvmeToHost, ch, t, "B from NVMe");
        }

        // Static output reservation: size of the larger compressed operand.
        let static_c = a.max(b);
        // Batch budget: what's left after resident B and the reservation.
        let avail = w.gpu_mem_bytes.saturating_sub(b + static_c);
        let batch = avail.max(256 << 20);
        let n_batches = a.div_ceil(batch).max(1);
        let partial_bytes = (w.avg_row_bytes() / 2.0) as u64;

        // B resident once per epoch (three-step dedup policy).
        let mut b_done = t;
        for ch in chunks(b, 4) {
            b_done = sim.transfer(cm, Op::HtoD, ch, b_done, "B resident");
        }

        let flops = w.spgemm_flops();
        let mut t = b_done;
        for _cycle in 0..w.cycles() {
            // The three-step data access policy keeps the gradient operand
            // cached on-device between fwd and bwd (no redundant re-send).
            let batch_ops = chunks(a, MAX_STREAM_OPS.min(n_batches as usize));
            let flops_batch = flops / batch_ops.len().max(1) as u64;
            let bytes_batch = (a + b + c) / batch_ops.len().max(1) as u64;
            let batches_per_op = (n_batches as usize).div_ceil(batch_ops.len().max(1)) as u64;
            let mut kernel_done = t;
            for ch in &batch_ops {
                // Inter-batch pipeline: H2D(i+1) only waits for the engine;
                // kernel(i) waits for its own H2D + kernel(i-1).
                let h2d = sim.transfer(cm, Op::HtoD, *ch, t, "A batch");
                kernel_done =
                    sim.gpu_kernel(cm, flops_batch, bytes_batch, kernel_done.max(h2d), "SpGEMM batch");
                // Reduced merge round-trip at batch boundaries (no
                // alignment, but far fewer cuts than MaxMemory).
                let merge = partial_bytes * batches_per_op;
                if merge > 0 {
                    kernel_done =
                        sim.transfer(cm, Op::DtoH, merge, kernel_done, "partial row back");
                    kernel_done = sim.transfer(cm, Op::HostMemcpy, 2 * merge, kernel_done, "merge");
                }
            }
            // Output leaves the GPU every cycle (static reservation is
            // recycled for the next batch set).
            for ch in chunks(c, 4) {
                kernel_done = sim.transfer(cm, Op::DtoH, ch, kernel_done, "C out");
            }
            t = sim.gpu_dense(cm, w.combine_flops(), kernel_done, "combine");
        }
        let _ = t;

        EpochResult::ok(self.name(), w, &sim, mem.peak)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graphgen::catalog::by_name;

    fn wl(name: &str) -> Workload {
        Workload::from_catalog(by_name(name).unwrap(), 256, 1)
    }

    #[test]
    fn survives_one_notch_below_static_allocators() {
        // Table III middle rows: ETC completes at kV1r@21, kP1a@14,
        // socLJ1@10 where MaxMemory/UCG OOM...
        let cm = CostModel::default();
        for (name, cap_gb) in [("kV1r", 21.0), ("kP1a", 14.0), ("socLJ1", 10.0)] {
            let mut w = wl(name);
            w.gpu_mem_bytes = (cap_gb * 1e9) as u64;
            assert!(Etc.run_epoch(&w, &cm).oom.is_none(), "{name}@{cap_gb}GB");
        }
    }

    #[test]
    fn ooms_at_the_tightest_level() {
        // ...but dies at kV1r@19, kP1a@12, socLJ1@8 (AIRES-only territory).
        let cm = CostModel::default();
        for (name, cap_gb) in [("kV1r", 19.0), ("kP1a", 12.0), ("socLJ1", 8.0)] {
            let mut w = wl(name);
            w.gpu_mem_bytes = (cap_gb * 1e9) as u64;
            assert!(Etc.run_epoch(&w, &cm).oom.is_some(), "{name}@{cap_gb}GB");
        }
    }

    #[test]
    fn b_crosses_pcie_once_per_epoch() {
        let cm = CostModel::default();
        let w = wl("kP1a");
        let r = Etc.run_epoch(&w, &cm);
        let h2d = r.io.get("HtoD").bytes;
        // HtoD = B once + A per cycle + grad once (+ merges): strictly less
        // than re-sending B every cycle like MaxMemory.
        assert!(h2d < w.b_bytes() * w.cycles() + w.a_bytes() * w.cycles() + w.c_bytes() * w.cycles());
        assert!(h2d > w.a_bytes() * w.cycles());
    }

    #[test]
    fn merge_traffic_smaller_than_maxmemory() {
        let cm = CostModel::default();
        let w = wl("kV2a");
        let etc = Etc.run_epoch(&w, &cm);
        let mm = super::super::MaxMemory.run_epoch(&w, &cm);
        // Compare non-C DtoH (merge round-trips only).
        let etc_merge = etc.io.get("DtoH").bytes.saturating_sub(w.c_bytes() * w.cycles());
        let mm_merge = mm.io.get("DtoH").bytes.saturating_sub(w.c_bytes() * w.cycles());
        assert!(etc_merge <= mm_merge);
    }
}
