//! Minimal JSON parser + writer (serde is unavailable in the offline cache).
//!
//! Supports the full JSON value model; used for the artifact manifest,
//! experiment configs, and report emission. Not performance-critical —
//! clarity over speed.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always carried as f64).
    Num(f64),
    /// String.
    Str(String),
    /// Array.
    Arr(Vec<Json>),
    /// Object (sorted keys, so emission is deterministic).
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Numeric value, `None` for any other variant.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }
    /// Numeric value truncated to usize.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }
    /// String value, `None` for any other variant.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }
    /// Boolean value, `None` for any other variant.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }
    /// Array elements, `None` for any other variant.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
    /// Object map, `None` for any other variant.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }
    /// Object field access, `None` if not an object or key missing.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }
}

/// Parse a JSON document. Returns `Err` with byte offset context on failure.
pub fn parse(input: &str) -> Result<Json, String> {
    let mut p = Parser { bytes: input.as_bytes(), pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(format!("trailing data at byte {}", p.pos));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.pos))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos)),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.bytes[self.pos..].starts_with(lit.as_bytes()) {
            self.pos += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.pos))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.pos += 1;
        }
        std::str::from_utf8(&self.bytes[start..self.pos])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    let esc = self.peek().ok_or("bad escape")?;
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or("bad \\u escape")?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|e| e.to_string())?,
                                16,
                            )
                            .map_err(|e| e.to_string())?;
                            self.pos += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        other => return Err(format!("bad escape '\\{}'", other as char)),
                    }
                }
                Some(_) => {
                    // Copy a run of plain bytes (UTF-8 passes through intact).
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(
                        std::str::from_utf8(&self.bytes[start..self.pos])
                            .map_err(|e| e.to_string())?,
                    );
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.pos)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.pos)),
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Json::Null => write!(f, "null"),
            Json::Bool(b) => write!(f, "{b}"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    write!(f, "{}", *n as i64)
                } else {
                    write!(f, "{n}")
                }
            }
            Json::Str(s) => {
                write!(f, "\"")?;
                for c in s.chars() {
                    match c {
                        '"' => write!(f, "\\\"")?,
                        '\\' => write!(f, "\\\\")?,
                        '\n' => write!(f, "\\n")?,
                        '\t' => write!(f, "\\t")?,
                        '\r' => write!(f, "\\r")?,
                        c if (c as u32) < 0x20 => write!(f, "\\u{:04x}", c as u32)?,
                        c => write!(f, "{c}")?,
                    }
                }
                write!(f, "\"")
            }
            Json::Arr(items) => {
                write!(f, "[")?;
                for (i, v) in items.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{v}")?;
                }
                write!(f, "]")
            }
            Json::Obj(map) => {
                write!(f, "{{")?;
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        write!(f, ",")?;
                    }
                    write!(f, "{}:{}", Json::Str(k.clone()), v)?;
                }
                write!(f, "}}")
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let v = parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"nested":{"t":true},"z":null}"#;
        let v = parse(src).unwrap();
        let printed = v.to_string();
        assert_eq!(parse(&printed).unwrap(), v);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }
}
