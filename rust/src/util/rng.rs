//! Deterministic PCG64-family PRNG.
//!
//! The offline crate cache has no `rand`, so we carry a small, well-tested
//! generator in-tree: PCG-XSH-RR 64/32 with a 64-bit stream selector.
//! Everything in the repo that needs randomness (graph generators, test
//! inputs, property-testing) goes through this type so runs are reproducible
//! from a single seed.

/// PCG-XSH-RR 64/32: 64-bit LCG state, 32-bit output with xorshift+rotate.
#[derive(Debug, Clone)]
pub struct Pcg {
    state: u64,
    inc: u64,
}

const PCG_MULT: u64 = 6364136223846793005;

impl Pcg {
    /// Create a generator from a seed and stream id (any values are fine).
    pub fn new(seed: u64, stream: u64) -> Self {
        let mut rng = Pcg { state: 0, inc: (stream << 1) | 1 };
        let _ = rng.next_u32();
        rng.state = rng.state.wrapping_add(seed);
        let _ = rng.next_u32();
        rng
    }

    /// Seed-only constructor (stream 0).
    pub fn seed(seed: u64) -> Self {
        Self::new(seed, 0)
    }

    /// Next raw 32-bit output of the PCG-XSH-RR stream.
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next 64 bits (two 32-bit outputs concatenated).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, bound)` via Lemire's multiply-shift with rejection.
    #[inline]
    pub fn below(&mut self, bound: u64) -> u64 {
        debug_assert!(bound > 0);
        // 128-bit multiply keeps the distribution exactly uniform.
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(bound as u128);
        let mut lo = m as u64;
        if lo < bound {
            let threshold = bound.wrapping_neg() % bound;
            while lo < threshold {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(bound as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below((hi - lo) as u64) as usize
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1u32 << 24) as f32)
    }

    /// Standard normal via Box-Muller (one value per call; simple > fast here).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = self.f64();
            if u > 1e-12 {
                let v = self.f64();
                return (-2.0 * u.ln()).sqrt() * (2.0 * std::f64::consts::PI * v).cos();
            }
        }
    }

    /// Bernoulli with probability `p`.
    #[inline]
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Snapshot the generator's `(state, increment)` pair — everything a
    /// checkpoint needs to resume the stream exactly where it left off.
    pub fn state(&self) -> (u64, u64) {
        (self.state, self.inc)
    }

    /// Rebuild a generator from a [`Self::state`] snapshot; the restored
    /// stream continues bit-for-bit from the snapshot point.
    pub fn from_state((state, inc): (u64, u64)) -> Pcg {
        Pcg { state, inc }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_for_seed() {
        let mut a = Pcg::seed(42);
        let mut b = Pcg::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn streams_differ() {
        let mut a = Pcg::new(42, 1);
        let mut b = Pcg::new(42, 2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "streams should decorrelate, {same} collisions");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Pcg::seed(7);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = rng.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval_mean() {
        let mut rng = Pcg::seed(3);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| rng.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg::seed(9);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn state_snapshot_resumes_the_stream_exactly() {
        let mut a = Pcg::seed(77);
        for _ in 0..13 {
            a.next_u64();
        }
        let snap = a.state();
        let mut b = Pcg::from_state(snap);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Pcg::seed(11);
        let mut xs: Vec<u32> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>());
    }
}
