//! Small shared utilities: PRNG, JSON, byte formatting, timing.

pub mod json;
pub mod rng;

/// Format a byte count as a human-readable string (binary units).
pub fn human_bytes(bytes: u64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = bytes as f64;
    let mut unit = 0;
    while v >= 1024.0 && unit < UNITS.len() - 1 {
        v /= 1024.0;
        unit += 1;
    }
    if unit == 0 {
        format!("{bytes} B")
    } else {
        format!("{v:.2} {}", UNITS[unit])
    }
}

/// Format seconds with an adaptive unit (ns/us/ms/s).
pub fn human_secs(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.3} s")
    } else if secs >= 1e-3 {
        format!("{:.3} ms", secs * 1e3)
    } else if secs >= 1e-6 {
        format!("{:.3} us", secs * 1e6)
    } else {
        format!("{:.0} ns", secs * 1e9)
    }
}

/// Nearest-rank percentile of an ascending-sorted sample set (`p` in
/// `[0, 100]`; `NaN` on an empty set). Deterministic: no interpolation,
/// just the sample at the scaled rank. Shared by the serve latency
/// report and the perf-trajectory statistics so both summarize samples
/// identically.
pub fn percentile(sorted: &[f64], p: f64) -> f64 {
    if sorted.is_empty() {
        return f64::NAN;
    }
    let p = p.clamp(0.0, 100.0);
    let rank = ((p / 100.0) * (sorted.len() as f64 - 1.0)).round() as usize;
    sorted[rank.min(sorted.len() - 1)]
}

/// Wall-clock stopwatch used by the bench harness and examples.
pub struct Stopwatch(std::time::Instant);

impl Stopwatch {
    /// Start timing now.
    pub fn start() -> Self {
        Stopwatch(std::time::Instant::now())
    }
    /// Seconds elapsed since [`Stopwatch::start`].
    pub fn secs(&self) -> f64 {
        self.0.elapsed().as_secs_f64()
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn human_bytes_units() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(2048), "2.00 KiB");
        assert_eq!(human_bytes(3 * 1024 * 1024 * 1024), "3.00 GiB");
    }

    #[test]
    fn human_secs_units() {
        assert_eq!(human_secs(2.5), "2.500 s");
        assert_eq!(human_secs(0.0025), "2.500 ms");
        assert_eq!(human_secs(2.5e-6), "2.500 us");
        assert_eq!(human_secs(5e-9), "5 ns");
    }
}
